"""Monitor unit tests: isolation, memory caps, evict/resume, checkpoint."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DeviceMemoryExceeded, Direction, FunkyCL,
                        FunkyRequest, GuestState, Monitor, MonitorError,
                        MonitorState, Program, RequestKind, SliceAllocator)


def _monitor(mem_cap=1 << 20):
    alloc = SliceAllocator("n0", 1, mem_cap_bytes=mem_cap)
    m = Monitor("task0", alloc)
    prog = Program("double", lambda x: x * 2.0)
    m.vfpga_init(prog, (jax.ShapeDtypeStruct((8,), jnp.float32),))
    return m


def test_execute_and_buffer_states():
    m = _monitor()
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.arange(8, dtype=np.float32))
    cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    out = cl.read_buffer("x")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.arange(8, dtype=np.float32) * 2)
    m.vfpga_exit()
    assert m.state is MonitorState.EXITED


def test_memory_cap_enforced():
    m = _monitor(mem_cap=100)
    cl = FunkyCL(m)
    with pytest.raises(DeviceMemoryExceeded):
        cl.clCreateBuffer("big", jax.ShapeDtypeStruct((1000,), jnp.float32))
        cl.clFinish()


def test_foreign_buffer_rejected():
    m = _monitor()
    cl = FunkyCL(m)
    with pytest.raises(MonitorError):
        cl.clEnqueueKernel("double", ("nope",), ("nope",))
        cl.clFinish()


def test_unknown_program_rejected():
    m = _monitor()
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.zeros(8, np.float32))
    with pytest.raises(MonitorError):
        cl.clEnqueueKernel("evil", ("x",), ("x",))
        cl.clFinish()


def test_evict_resume_preserves_values_and_frees_slot():
    alloc = SliceAllocator("n0", 1)
    m = Monitor("t", alloc)
    m.vfpga_init(Program("double", lambda x: x * 2.0),
                 (jax.ShapeDtypeStruct((8,), jnp.float32),))
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.ones(8, np.float32))
    cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    assert alloc.free_count() == 0
    stats = m.evict()
    assert alloc.free_count() == 1            # slot released
    assert stats["n_dirty"] == 1
    assert m.state is MonitorState.EVICTED
    m.resume()
    cl2 = FunkyCL(m)
    np.testing.assert_array_equal(np.asarray(cl2.read_buffer("x")),
                                  np.full(8, 2.0, np.float32))


def test_evict_skips_clean_buffers():
    m = _monitor()
    cl = FunkyCL(m)
    cl.clCreateBuffer("input", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("input", np.ones(8, np.float32))   # SYNC after h2d
    cl.clFinish()
    stats = m.evict()
    assert stats["saved_bytes"] == 0
    assert stats["skipped_bytes"] == 32


def test_checkpoint_keep_running():
    m = _monitor()
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.ones(8, np.float32))
    cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    snap = m.checkpoint(GuestState(step=3), keep_running=True)
    assert m.state is MonitorState.RUNNING
    assert snap.step == 3
    np.testing.assert_array_equal(snap.buffers["x"], np.full(8, 2.0))
    # still usable afterwards
    cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    np.testing.assert_array_equal(np.asarray(cl.read_buffer("x")),
                                  np.full(8, 4.0, np.float32))


def test_no_slice_available():
    from repro.core import NoSliceAvailable

    alloc = SliceAllocator("n0", 1)
    m1 = Monitor("a", alloc)
    m1.vfpga_init(Program("id", lambda x: x),
                  (jax.ShapeDtypeStruct((2,), jnp.float32),))
    m2 = Monitor("b", alloc)
    with pytest.raises(NoSliceAvailable):
        m2.vfpga_init(Program("id2", lambda x: x),
                      (jax.ShapeDtypeStruct((2,), jnp.float32),))


def test_sync_blocks_only_buffers_written_since_last_sync():
    """SYNC drains the dirty-since-last-sync set, not the whole table."""
    m = _monitor()
    cl = FunkyCL(m)
    cl.clCreateBuffer("a", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.clCreateBuffer("b", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("a", np.ones(8, np.float32))
    cl.write_buffer("b", np.ones(8, np.float32))
    cl.clFinish()
    assert m.buffers.unsynced_count() == 0
    cl.clEnqueueKernel("double", ("a",), ("a",))
    # queue the sync behind the execute; only "a" is pending
    req = FunkyRequest(kind=RequestKind.SYNC)
    m.submit(req)
    pending_before = m.buffers.unsynced_count()
    req.completion.wait()
    assert pending_before <= 1           # b was never re-dirtied
    assert m.buffers.unsynced_count() == 0


def test_exec_signature_cache_invalidated_on_reshape():
    """A shape-changing h2d bumps the spec token; the cached signature is
    dropped and the request recompiles instead of calling a stale entry."""
    m = _monitor()
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.ones(8, np.float32))
    cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    misses0 = m.programs.stats["misses"]
    cl.write_buffer("x", np.ones(4, np.float32))    # reshape
    cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    assert m.programs.stats["misses"] == misses0 + 1
    np.testing.assert_array_equal(np.asarray(cl.read_buffer("x")),
                                  np.full(4, 2.0, np.float32))


def test_shape_changing_inplace_program_never_replays_stale_entry():
    """A program that writes a different shape back into its own input
    must miss the signature cache every call (compiled-entry avals can't
    be replayed against the grown buffer)."""
    m = _monitor()
    m.register_program(Program("grow", lambda x: jnp.concatenate([x, x])),
                       (jax.ShapeDtypeStruct((8,), jnp.float32),))
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.ones(8, np.float32))
    for _ in range(3):
        cl.clEnqueueKernel("grow", ("x",), ("x",))
    cl.clFinish()
    assert np.asarray(cl.read_buffer("x")).shape == (64,)


def test_same_shape_h2d_keeps_signature_cache_warm():
    m = _monitor()
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    for i in range(3):
        cl.write_buffer("x", np.full(8, float(i), np.float32))
        cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    assert m.metrics["exec_sig_cache_hits"] >= 2


def test_donated_execute_roundtrip():
    """donate=True updates in place; values stay correct and the buffer
    survives evict/resume."""
    alloc = SliceAllocator("n0", 1)
    m = Monitor("t", alloc)
    m.vfpga_init(Program("double", lambda x: x * 2.0),
                 (jax.ShapeDtypeStruct((8,), jnp.float32),),
                 donate_argnums=(0,))
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.ones(8, np.float32))
    for _ in range(3):
        cl.clEnqueueKernel("double", ("x",), ("x",), donate=True)
    cl.clFinish()
    np.testing.assert_array_equal(np.asarray(cl.read_buffer("x")),
                                  np.full(8, 8.0, np.float32))
    # only the donate_argnums=(0,) variant was compiled (no double compile)
    keys = [(pid, d) for (pid, _, d) in m.programs._compiled.keys()]
    assert keys.count(("double", (0,))) == 1
    assert ("double", ()) not in keys
    m.evict()
    m.resume()
    cl2 = FunkyCL(m)
    cl2.clEnqueueKernel("double", ("x",), ("x",), donate=True)
    cl2.clFinish()
    np.testing.assert_array_equal(np.asarray(cl2.read_buffer("x")),
                                  np.full(8, 16.0, np.float32))


def test_program_cache_hit_is_warm():
    m = _monitor()
    stats0 = dict(m.programs.stats)
    cl = FunkyCL(m)
    cl.clCreateBuffer("x", jax.ShapeDtypeStruct((8,), jnp.float32))
    cl.write_buffer("x", np.ones(8, np.float32))
    for _ in range(3):
        cl.clEnqueueKernel("double", ("x",), ("x",))
    cl.clFinish()
    stats = m.programs.stats
    assert stats["misses"] == stats0["misses"]   # compiled at vfpga_init
    # first EXECUTE fingerprints once; the monitor's signature cache then
    # short-circuits the per-request abstract walk entirely
    assert stats["hits"] == stats0["hits"] + 1
    assert m.metrics["exec_sig_cache_hits"] >= 2
