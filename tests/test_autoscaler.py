"""Workload-scaling service: policies, hysteresis/cooldown, bounds, the
reconcile contract against a (fake) orchestrator, and the simulator-in-the-
loop smoke run (Fig 14 machinery)."""

import math

import pytest

from repro.core.simulator import ServingParams, ServingSimulator
from repro.scaling import (Autoscaler, LatencySLOPolicy, MetricsRegistry,
                           QueueLengthPolicy, ScalingSignals,
                           TargetUtilizationPolicy, burst_rate, open_loop,
                           signals_from_registry)


def sig(replicas=1, util=0.0, queue=0.0, p95=math.nan):
    return ScalingSignals(replicas=replicas, utilization=util,
                          queue_depth=queue, p95_latency_s=p95)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
def test_target_utilization_proportional():
    p = TargetUtilizationPolicy(target=0.6)
    assert p.desired_replicas(sig(replicas=4, util=0.9)) == 6
    assert p.desired_replicas(sig(replicas=4, util=0.3)) == 2
    # idle with empty queue collapses to 1
    assert p.desired_replicas(sig(replicas=4, util=0.0)) == 1


def test_queue_length_policy():
    p = QueueLengthPolicy(target_per_replica=2.0)
    # 9 outstanding / 3-per-replica budget -> 3 replicas
    assert p.desired_replicas(sig(replicas=2, util=1.0, queue=7.0)) == 3
    assert p.desired_replicas(sig(replicas=4, util=0.0, queue=0.0)) == 1


def test_latency_slo_scale_up_on_spike():
    p = LatencySLOPolicy(slo_p95_s=0.5, growth=1.5)
    s = sig(replicas=2, util=1.0, queue=10.0, p95=2.0)
    assert p.desired_replicas(s) == 3            # ceil(2 * 1.5)
    # no latency signal yet -> hold
    assert p.desired_replicas(sig(replicas=2, util=0.9, queue=1.0)) == 2


def test_latency_slo_scale_down_needs_headroom_and_idle():
    p = LatencySLOPolicy(slo_p95_s=1.0, headroom=0.5, idle_utilization=0.5)
    assert p.desired_replicas(sig(replicas=4, util=0.2, p95=0.1)) == 3
    # tail fine but still busy -> hold
    assert p.desired_replicas(sig(replicas=4, util=0.9, p95=0.1)) == 4
    # queued work -> hold even when idle-ish
    assert p.desired_replicas(sig(replicas=4, util=0.2, queue=3.0,
                                  p95=0.1)) == 4


# ---------------------------------------------------------------------------
# reconciler
# ---------------------------------------------------------------------------
def test_scale_up_on_load_spike():
    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=0.5), max_replicas=8)
    got = asc.reconcile(sig(replicas=2, util=1.0, queue=5.0, p95=3.0),
                        now=0.0)
    assert got is not None and got > 2


def test_scale_down_only_after_cooldown():
    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=1.0),
                     scale_down_cooldown_s=30.0)
    idle = sig(replicas=4, util=0.1, p95=0.05)
    assert asc.reconcile(idle, now=0.0) == 3         # first down: free
    assert asc.reconcile(idle, now=10.0) is None     # inside cooldown
    assert asc.reconcile(idle, now=31.0) == 3        # cooldown elapsed
    reasons = [d.reason for d in asc.decisions]
    assert "down-cooldown" in reasons


def test_scale_up_rearms_shrink_guard():
    """After a burst-driven scale-up, the first shrink must wait out the
    down-cooldown (anti-flap), instead of firing immediately."""
    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=0.5),
                     scale_down_cooldown_s=30.0, max_replicas=8)
    assert asc.reconcile(sig(replicas=2, util=1.0, queue=9.0, p95=2.0),
                         now=0.0) == 3             # burst: scale up
    idle = sig(replicas=3, util=0.1, p95=0.05)
    assert asc.reconcile(idle, now=5.0) is None    # guard re-armed by up
    assert asc.reconcile(idle, now=31.0) == 2      # cooldown elapsed


def test_scale_up_cooldown():
    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=0.5),
                     scale_up_cooldown_s=10.0, max_replicas=16)
    hot = sig(replicas=2, util=1.0, queue=9.0, p95=2.0)
    assert asc.reconcile(hot, now=0.0) == 3
    assert asc.reconcile(sig(replicas=3, util=1.0, queue=9.0, p95=2.0),
                         now=1.0) is None            # up-cooldown
    assert asc.reconcile(sig(replicas=3, util=1.0, queue=9.0, p95=2.0),
                         now=11.0) == 5


def test_bounds_never_exceeded():
    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=0.1), min_replicas=2,
                     max_replicas=5, scale_down_cooldown_s=0.0)
    replicas = 2
    for i in range(20):              # persistent SLO breach
        got = asc.reconcile(sig(replicas=replicas, util=1.0, queue=50.0,
                                p95=9.0), now=float(i))
        if got is not None:
            replicas = got
        assert 2 <= replicas <= 5
    assert replicas == 5
    # persistent idle never goes below min
    for i in range(20, 40):
        got = asc.reconcile(sig(replicas=replicas, util=0.0, p95=0.0),
                            now=float(i))
        if got is not None:
            replicas = got
        assert replicas >= 2


def test_tolerance_dead_band():
    asc = Autoscaler(TargetUtilizationPolicy(target=0.5), tolerance=0.3,
                     max_replicas=32)
    # desired 12 vs current 10: |2|/10 <= 0.3 -> hold
    assert asc.reconcile(sig(replicas=10, util=0.6), now=0.0) is None
    # desired 20 vs current 10: outside the band -> act
    assert asc.reconcile(sig(replicas=10, util=1.0), now=1.0) == 20


# ---------------------------------------------------------------------------
# reconcile contract against a (fake) live orchestrator
# ---------------------------------------------------------------------------
class _FakeDep:
    def __init__(self):
        self.status = "running"


class _FakeOrch:
    """Duck-typed Orchestrator surface used by OrchestratorScaler."""

    def __init__(self, free_nodes=4):
        self.metrics = MetricsRegistry()
        self.deployments = {"svc-base": _FakeDep()}
        self._free = free_nodes
        self._n = 0
        self.removed = []

    def place_replica(self, cid):
        return f"node{self._free}" if self._free > 0 else None

    def scale_horizontal(self, cid, node):
        assert self._free > 0
        self._free -= 1
        self._n += 1
        new_cid = f"{cid}-r{self._n}"
        self.deployments[new_cid] = _FakeDep()
        return new_cid

    def scale_in(self, cid, drain_s=0.0):
        self.deployments[cid].status = "removed"
        self._free += 1
        self.removed.append(cid)


def test_orchestrator_scaler_scale_out_and_in():
    from repro.scaling.autoscaler import OrchestratorScaler

    orch = _FakeOrch(free_nodes=3)
    scaler = OrchestratorScaler(orch, "svc-base", service="svc")
    assert scaler.current_replicas() == 1
    scaler.scale_to(3)
    assert scaler.current_replicas() == 3
    scaler.scale_to(5)                   # only one free slot left
    assert scaler.current_replicas() == 4
    scaler.scale_to(1)                   # base is never removed
    assert scaler.current_replicas() == 1
    assert len(orch.removed) == 3
    assert orch.metrics.gauge("replicas", service="svc").value == 1


# ---------------------------------------------------------------------------
# simulator in the loop (Fig 14 smoke)
# ---------------------------------------------------------------------------
def test_serving_simulator_autoscaler_smoke():
    reqs = open_loop(burst_rate(3.0, 6.0, 30.0, 30.0), 90.0, seed=7,
                     mean_service_s=0.25)
    params = ServingParams(slo_latency_s=1.0, control_interval_s=1.0)

    fixed = ServingSimulator(reqs, initial_replicas=2, params=params).run()

    asc = Autoscaler(LatencySLOPolicy(slo_p95_s=1.0), min_replicas=1,
                     max_replicas=10, scale_down_cooldown_s=5.0)
    elastic = ServingSimulator(
        reqs, autoscaler=asc, initial_replicas=2, params=params).run()

    assert fixed["completed"] == elastic["completed"] == len(reqs)
    assert elastic["slo_attainment"] > fixed["slo_attainment"]
    assert elastic["max_replicas"] <= 10
    # scaled back down after the burst
    assert elastic["mean_replicas"] < 10
    assert any(d.applied for d in asc.decisions)


def test_serving_simulator_emits_canonical_schema():
    reqs = open_loop(burst_rate(2.0, 4.0, 10.0, 10.0), 30.0, seed=3,
                     mean_service_s=0.2)
    asc = Autoscaler(TargetUtilizationPolicy(0.6), max_replicas=6)
    sim = ServingSimulator(reqs, autoscaler=asc, initial_replicas=1)
    sim.run()
    snap = sim.metrics.snapshot()
    assert snap["ts"] == sim.now                       # virtual clock
    assert snap["counters"]["requests_total{service=svc}"] == len(reqs)
    assert "queue_depth{service=svc}" in snap["gauges"]
    assert "utilization{service=svc}" in snap["gauges"]
    assert "request_latency_seconds{service=svc}" in snap["histograms"]
    assert "replicas_ts{service=svc}" in snap["series"]
    # the signal reader the orchestrator uses works against the sim registry
    s = signals_from_registry(sim.metrics, "svc")
    assert s.replicas >= 1


def test_closed_loop_gen_tokens_and_conservation():
    """Closed-loop think-time mode: ragged generation lengths ride along
    (engine-served runs), and the simulator completes exactly the requests
    the generator issued — the defining closed-loop property."""
    from repro.scaling import ClosedLoopGen

    gen = ClosedLoopGen(n_clients=6, think_time_s=0.2, mean_service_s=0.1,
                        horizon_s=20.0, seed=3, tokens_range=(4, 9))
    init = gen.initial()
    assert len(init) == 6
    assert all(4 <= r.n_tokens < 9 for r in init)
    rep = ServingSimulator(init, closed_gen=gen,
                           initial_replicas=2).run()
    assert rep["completed"] == gen.issued > 6


# ---------------------------------------------------------------------------
# cache-memory occupancy: KV pool model + pressure signal/policy
# ---------------------------------------------------------------------------
def test_kv_pressure_policy_composes():
    from repro.scaling.autoscaler import KVPressurePolicy

    p = KVPressurePolicy(inner=QueueLengthPolicy(target_per_replica=2.0),
                         high_watermark=0.8)
    calm = sig(replicas=2)
    calm.kv_pressure = 0.5
    assert p.desired_replicas(calm) == p.inner.desired_replicas(calm)
    hot = sig(replicas=2)
    hot.kv_pressure = 0.95                 # pool nearly full, queue empty
    assert p.desired_replicas(hot) == 3


def test_serving_simulator_kv_pool_model():
    """A tight pool shows up as the canonical kv signal, blocks admission
    on memory, and OOM-preempts growing requests — which the autoscaler
    relieves by adding replicas (capacity = replicas x pool_pages)."""
    from repro.core.simulator import KVModelParams
    from repro.scaling.autoscaler import (KVPressurePolicy,
                                          signals_from_registry)

    reqs = open_loop(burst_rate(3.0, 5.0, 3.0, 8.0), 20.0, seed=5,
                     mean_service_s=0.4, tokens_range=(8, 33))
    kv = KVModelParams(pool_pages=5, page_tokens=8, prompt_tokens=16,
                       default_tokens=16)
    fixed = ServingSimulator(reqs, initial_replicas=2, kv_model=kv)
    fixed_rep = fixed.run()
    assert fixed_rep["completed"] == len(reqs)         # preempts, finishes
    assert fixed_rep["kv_peak_occupancy"] > 0.9        # pool genuinely hot
    assert fixed_rep["kv_preemptions"] > 0
    snap = fixed.metrics.snapshot()
    assert "kv_pages_in_use_ratio{service=svc}" in snap["gauges"]
    s = signals_from_registry(fixed.metrics, "svc")
    assert 0.0 <= s.kv_pressure <= 1.0

    asc = Autoscaler(KVPressurePolicy(QueueLengthPolicy(2.0),
                                      high_watermark=0.8),
                     max_replicas=8, scale_down_cooldown_s=5.0)
    elastic = ServingSimulator(reqs, autoscaler=asc, initial_replicas=2,
                               kv_model=kv).run()
    assert elastic["completed"] == len(reqs)
    assert elastic["max_replicas"] > 2                 # pressure scaled out
    assert elastic["kv_preemptions"] <= fixed_rep["kv_preemptions"]


# ---------------------------------------------------------------------------
# speculative decode in the service model
# ---------------------------------------------------------------------------
def test_engine_service_model_speculation_speedup():
    """Speculation divides the per-token time by the expected committed
    tokens per iteration, E = sum a^i: 1 at a=0 (plain), k+1 at a=1."""
    from repro.core.simulator import (engine_service_model,
                                      spec_tokens_per_iteration)
    from repro.scaling.loadgen import Request

    assert spec_tokens_per_iteration(2, 0.0) == 1.0
    assert spec_tokens_per_iteration(2, 1.0) == 3.0
    assert spec_tokens_per_iteration(3, 0.5) == pytest.approx(1.875)

    req = Request(rid="r", arrival_t=0.0, service_s=1.0, n_tokens=9)
    plain = engine_service_model(0.1, 0.02)
    spec_off = engine_service_model(0.1, 0.02, spec_k=0,
                                    spec_accept_rate=0.9)
    forced = engine_service_model(0.1, 0.02, spec_k=2, spec_accept_rate=1.0)
    assert plain(req) == spec_off(req) == pytest.approx(0.1 + 8 * 0.02)
    assert forced(req) == pytest.approx(0.1 + 8 * 0.02 / 3.0)
    # acceptance clamps to [0, 1]
    wild = engine_service_model(0.1, 0.02, spec_k=2, spec_accept_rate=7.0)
    assert wild(req) == forced(req)


def test_serving_simulator_publishes_spec_accept_gauge():
    from repro.core.simulator import engine_service_model
    from repro.scaling.autoscaler import M_SPEC_ACCEPT_RATE

    reqs = open_loop(burst_rate(2.0, 3.0, 5.0, 5.0), 15.0, seed=9,
                     mean_service_s=0.2, tokens_range=(4, 9))
    spec = ServingSimulator(
        reqs, initial_replicas=2,
        service_time_fn=engine_service_model(0.05, 0.02, spec_k=2,
                                             spec_accept_rate=0.7),
        spec_accept_rate=0.7)
    rep = spec.run()
    assert rep["completed"] == len(reqs)
    snap = spec.metrics.snapshot()
    assert snap["gauges"][f"{M_SPEC_ACCEPT_RATE}{{service=svc}}"] == 0.7
    # faster service at equal traffic: speculation strictly helps the tail
    plain = ServingSimulator(
        reqs, initial_replicas=2,
        service_time_fn=engine_service_model(0.05, 0.02)).run()
    assert rep["p95_latency_s"] <= plain["p95_latency_s"]
