"""Span tracing: virtual-clock determinism, bounded retention (ring +
keep-slowest + probabilistic sampling), Chrome-trace export round-trip,
and end-to-end instrumentation — a router->engine->monitor request forms
one connected span tree, monitor phase attribution sums to no more than
the handler wall time, and the engine's host/device split is publishable."""

import json
import os
import tempfile

import numpy as np
import pytest

from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.core.simulator import ServingSimulator
from repro.obs import (Tracer, chrome_trace_events, export_chrome_trace,
                       validate_chrome_trace)
from repro.scaling import burst_rate, open_loop
from repro.scaling.metrics import MetricsRegistry
from repro.scaling.serving import RequestRouter
from repro.serve.engine import (M_DEVICE_US, M_HOST_US, M_QUEUE_WAIT_US,
                                ContinuousBatchingEngine, ServeRequest)

ARCH = "yi-9b-smoke"
PROMPT_LEN = 8
PAGE = 4


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


# ---------------------------------------------------------------------------
# Tracer core under a virtual clock
# ---------------------------------------------------------------------------
def test_span_tree_virtual_clock_deterministic():
    clk = FakeClock()
    tracer = Tracer(clock=clk)
    tr = tracer.start_trace("request", trace_id="r0", service="svc")
    assert tr.root.start_t == 0.0 and tr.root.parent_id == 0

    clk.now = 1.0
    queue = tr.span("router.queue")
    clk.now = 3.0
    queue.end()
    admit = tr.span("engine.admit", engine="eng0")
    clk.now = 3.5
    ex = admit.child("monitor.execute", program="decode")
    clk.now = 4.0
    ex.end()
    admit.end()
    clk.now = 6.0
    tr.finish(tokens=4)

    # exact virtual timestamps, not wall-clock noise
    assert queue.start_t == 1.0 and queue.end_t == 3.0
    assert queue.duration == 2.0
    assert ex.start_t == 3.5 and ex.duration == 0.5
    assert tr.duration == 6.0 and tr.finished

    # tree shape: root <- {queue, admit}, admit <- execute
    spans = tr.spans()
    assert spans[0] is tr.root
    by_id = {s.span_id: s for s in spans}
    assert by_id[queue.parent_id] is tr.root
    assert by_id[admit.parent_id] is tr.root
    assert by_id[ex.parent_id] is admit
    # a second identical run produces the identical tree
    clk2 = FakeClock()
    t2 = Tracer(clock=clk2).start_trace("request", trace_id="r0")
    s2 = t2.span("router.queue")
    assert (s2.span_id, s2.parent_id) == (queue.span_id, queue.parent_id)


def test_parent_defaults_to_root_and_context_manager():
    clk = FakeClock()
    tr = Tracer(clock=clk).start_trace("t")
    with tr.span("a") as sp:
        clk.now = 2.0
    assert sp.end_t == 2.0
    assert sp.end(t=99.0).end_t == 2.0          # end() is idempotent
    assert sp.parent_id == tr.root.span_id


def test_trace_span_ring_never_evicts_root():
    clk = FakeClock()
    tracer = Tracer(clock=clk, max_spans_per_trace=4)
    tr = tracer.start_trace("hot", trace_id="h")
    for i in range(10):
        tr.span(f"s{i}").end()
    spans = tr.spans()
    assert spans[0] is tr.root                  # root survives eviction
    assert len(spans) == 1 + 4
    assert [s.name for s in spans[1:]] == ["s6", "s7", "s8", "s9"]
    assert tr.dropped_spans == 6


def test_ring_capacity_and_keep_slowest():
    clk = FakeClock()
    tracer = Tracer(clock=clk, capacity=4, sample_rate=1.0, keep_slowest=2)
    durs = [1.0, 9.0, 2.0, 7.0, 3.0, 0.5, 0.25, 0.125]
    for i, d in enumerate(durs):
        clk.now = 10.0 * i
        tr = tracer.start_trace("t", trace_id=f"t{i}")
        clk.now = 10.0 * i + d
        tr.finish()
    kept = tracer.traces()
    ids = {t.trace_id for t in kept}
    # ring holds the 4 most recent; the slowest two (t1, t3) are retained
    # by the keep-slowest heap even though the ring evicted them
    assert {"t4", "t5", "t6", "t7"} <= ids
    assert {"t1", "t3"} <= ids
    assert "t0" not in ids and "t2" not in ids


def test_probabilistic_sampling_bounds_and_determinism():
    def run(seed):
        tracer = Tracer(clock=FakeClock(), capacity=1000, sample_rate=0.25,
                        keep_slowest=0, seed=seed)
        for i in range(400):
            tracer.start_trace("t", trace_id=f"t{i}").finish()
        return [t.trace_id for t in tracer.traces()]

    a, b = run(7), run(7)
    assert a == b                                # seeded => deterministic
    assert 40 <= len(a) <= 160                   # ~100 expected of 400
    # sample_rate=0 keeps nothing through the ring...
    t0 = Tracer(clock=FakeClock(), sample_rate=0.0, keep_slowest=0)
    for i in range(10):
        t0.start_trace("t").finish()
    assert t0.traces() == [] and t0.finished == 10
    # ...but keep-slowest still catches outliers
    clk = FakeClock()
    t1 = Tracer(clock=clk, sample_rate=0.0, keep_slowest=1)
    tr = t1.start_trace("slow")
    clk.now = 5.0
    tr.finish()
    assert [t.trace_id for t in t1.traces()] == [tr.trace_id]


def test_live_traces_visible_until_finished():
    tracer = Tracer(clock=FakeClock())
    tr = tracer.start_trace("inflight", trace_id="x")
    assert tracer.find("x") is tr
    assert tracer.traces(include_live=False) == []
    tr.finish()
    assert tracer.find("x") is tr


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------
def test_chrome_export_round_trip(tmp_path):
    clk = FakeClock()
    tracer = Tracer(clock=clk)
    tr = tracer.start_trace("request", trace_id="r9", service="svc")
    clk.now = 0.25
    sp = tr.span("engine.admit", engine="e0")
    clk.now = 0.75
    sp.end()
    unfinished = tr.span("engine.decode")
    clk.now = 1.0
    tr.finish(tokens=3)

    path = tmp_path / "trace.json"
    export_chrome_trace(tracer, str(path))
    doc = json.loads(path.read_text())
    stats = validate_chrome_trace(doc)
    assert stats == {"traces": 1, "spans": 3}
    assert doc["displayTimeUnit"] == "ms"

    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name"} <= {e["name"] for e in meta}
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"request", "engine.admit", "engine.decode"}
    adm = xs["engine.admit"]
    assert adm["ts"] == pytest.approx(0.25e6)
    assert adm["dur"] == pytest.approx(0.5e6)
    assert adm["args"]["engine"] == "e0"
    assert adm["args"]["parent_id"] == xs["request"]["args"]["span_id"]
    assert adm["pid"] == xs["request"]["pid"]          # same process row
    assert adm["tid"] != xs["request"]["tid"]          # own name-prefix row
    assert unfinished.end_t is None             # intentionally left open
    assert xs["engine.decode"]["args"]["unfinished"] is True
    assert xs["engine.decode"]["dur"] == pytest.approx(0.25e6)


def test_validate_rejects_orphans_and_bad_ph():
    doc = chrome_trace_events([])
    doc["traceEvents"].append({"name": "x", "ph": "B", "pid": 1, "tid": 1})
    with pytest.raises(ValueError, match="unexpected ph"):
        validate_chrome_trace(doc)
    orphan = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": 1,
         "args": {"span_id": 2, "parent_id": 1, "trace_id": "t"}}]}
    with pytest.raises(ValueError, match="orphaned|root"):
        validate_chrome_trace(orphan)


# ---------------------------------------------------------------------------
# Simulator (virtual clock) publishes into the same abstraction
# ---------------------------------------------------------------------------
def test_simulator_traces_deterministic_virtual_time():
    reqs = open_loop(burst_rate(3.0, 2.0, 3.0, 3.0), 10.0, seed=5,
                     mean_service_s=0.2)

    def run():
        sim = ServingSimulator(list(reqs), initial_replicas=2, trace=True)
        sim.run()
        return sim.tracer

    tr1, tr2 = run(), run()
    done1 = [t for t in tr1.traces() if t.finished]
    assert done1, "simulator produced no finished request traces"
    t = done1[0]
    names = [s.name for s in t.spans()]
    assert "router.queue" in names and "sim.service" in names
    assert "latency_s" in t.root.labels
    # virtual clock => two runs give bit-identical span timings
    d1 = [x.to_dict() for x in tr1.traces() if x.finished]
    d2 = [x.to_dict() for x in tr2.traces() if x.finished]
    assert d1 == d2
    validate_chrome_trace(tr1.chrome_trace())


# ---------------------------------------------------------------------------
# Live plane: router -> engine -> monitor, one connected tree per request
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    tracer = Tracer(capacity=512, sample_rate=1.0)
    reg = MetricsRegistry()
    mon = Monitor("obs-test", SliceAllocator("n0", 1), telemetry=reg,
                  tracer=tracer)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=2,
                                   prompt_len=PROMPT_LEN, max_new_tokens=8,
                                   registry=reg, page_size=PAGE)
    eng.setup()
    router = RequestRouter("svc", registry=reg, kv_aware=False,
                           tracer=tracer)
    rng = np.random.Generator(np.random.Philox(0))
    for i, n in enumerate([2, 5, 3]):
        router.submit(ServeRequest(
            rid=f"r{i}", prompt=rng.integers(0, 100, PROMPT_LEN),
            max_new_tokens=n))
    while router.outstanding() or not eng.idle:
        eng.pump(router)
    mon.vfpga_exit()
    path = tmp_path_factory.mktemp("obs") / "live.json"
    export_chrome_trace(tracer, str(path))
    return tracer, eng, reg, json.loads(path.read_text())


def test_request_trace_is_one_connected_tree(traced_run):
    tracer, eng, _, _ = traced_run
    assert sorted(eng.completed) == ["r0", "r1", "r2"]
    for rid in ("r0", "r1", "r2"):
        tr = tracer.find(rid)
        assert tr is not None and tr.finished
        spans = tr.spans()
        ids = {s.span_id for s in spans}
        for s in spans:
            assert s.parent_id == 0 or s.parent_id in ids, \
                f"{rid}: span {s.name} orphaned"
        names = {s.name for s in spans}
        # router -> engine -> monitor chain present in ONE trace
        assert {"router.queue", "engine.queue", "engine.admit",
                "engine.decode", "monitor.execute",
                "execute.device"} <= names
        # every span closed, nested within the root window
        for s in spans:
            assert s.end_t is not None
            assert s.end_t >= s.start_t
            assert s.end_t <= tr.root.end_t + 1e-9
        assert tr.root.labels["tokens"] == \
            len(eng.completed[rid].tokens)


def test_exported_live_trace_validates(traced_run):
    _, _, _, doc = traced_run
    stats = validate_chrome_trace(doc)
    assert stats["traces"] >= 3                 # 3 requests + step traces
    execs = [e for e in doc["traceEvents"]
             if e.get("ph") == "X" and e["name"] == "execute.device"]
    assert execs and any(e["dur"] > 0 for e in execs)


def test_iteration_traces_cover_decode_steps(traced_run):
    tracer, eng, _, _ = traced_run
    its = [t for t in tracer.traces()
           if t.name == "engine.step" and t.finished]
    assert its, "no per-iteration engine.step traces"
    assert all(t.trace_id.startswith(eng.engine_id) for t in its)
    decoded = sum(t.root.labels.get("decoded", 0) for t in its)
    admitted = sum(t.root.labels.get("admitted", 0) for t in its)
    total = sum(len(rec.tokens) for rec in eng.completed.values())
    assert decoded + admitted == total


def test_phase_attribution_bounded_by_wall_time(traced_run):
    tracer, _, _, _ = traced_run
    for tr in tracer.traces():
        for mon_span in tr.find_spans("monitor.execute"):
            kids = [s for s in tr.spans()
                    if s.parent_id == mon_span.span_id]
            assert kids, "monitor.execute has no phase children"
            for k in kids:
                assert k.duration >= 0.0
            assert sum(k.duration for k in kids) \
                <= mon_span.duration + 1e-6


def test_host_device_split_published(traced_run):
    _, eng, reg, _ = traced_run
    split = eng.host_device_split()
    total = sum(len(rec.tokens) for rec in eng.completed.values())
    assert split["tokens"] == total
    assert split["execs"] > 0
    assert split["device_us_per_token"] > 0.0
    assert split["host_us_per_token"] >= 0.0
    text = reg.to_prometheus_text()
    assert M_HOST_US in text and M_DEVICE_US in text
    assert (f'{M_DEVICE_US}{{engine="{eng.engine_id}",service="svc"}}'
            in text)


def test_queue_wait_gauge_denominator_counts_only_executes(traced_run):
    """The queue-wait gauge averages per-EXECUTE queue time.  The
    denominator must be the EXECUTE tally — it used to add every
    completion the step saw (writes, reads, syncs), diluting the gauge by
    the transfer traffic of the same iteration."""
    _, eng, reg, _ = traced_run
    split = eng.host_device_split()
    assert eng._attr_reqs == eng._attr_execs == split["execs"]
    assert split["queue_wait_us_mean"] == pytest.approx(
        eng._attr_queue_wait_s / split["execs"] * 1e6)
    val = reg.gauge(M_QUEUE_WAIT_US, service="svc",
                    engine=eng.engine_id).value
    assert val == pytest.approx(split["queue_wait_us_mean"])


def test_engine_crash_dumps_flight_record(monkeypatch):
    """An unexpected step() exception must leave the event ring on disk
    (the post-mortem) before the error reaches the caller."""
    reg = MetricsRegistry()
    mon = Monitor("obs-crash", SliceAllocator("n2", 1), telemetry=reg)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=1,
                                   prompt_len=PROMPT_LEN, max_new_tokens=4,
                                   registry=reg, page_size=PAGE)
    eng.setup()
    reg.record_event("engine_admit", rid="x", slot=0)

    def boom():
        raise RuntimeError("boom")

    monkeypatch.setattr(eng, "_step_inner", boom)
    with pytest.raises(RuntimeError, match="boom"):
        eng.step()
    path = os.path.join(tempfile.gettempdir(),
                        f"funky_flight_{eng.engine_id}.json")
    with open(path) as f:
        doc = json.load(f)
    os.unlink(path)
    assert "RuntimeError" in doc["context"]["error"]
    assert doc["context"]["engine"] == eng.engine_id
    assert any(e["kind"] == "engine_admit" for e in doc["events"])
    mon.vfpga_exit()


def test_untraced_engine_still_attributes_phases():
    """No tracer anywhere: the split still comes from Completion.phases."""
    reg = MetricsRegistry()
    mon = Monitor("obs-plain", SliceAllocator("n1", 1), telemetry=reg)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=2,
                                   prompt_len=PROMPT_LEN, max_new_tokens=6,
                                   registry=reg, page_size=PAGE)
    eng.setup()
    assert eng.tracer is None
    rng = np.random.Generator(np.random.Philox(1))
    eng.submit(ServeRequest(rid="p0", prompt=rng.integers(0, 100, PROMPT_LEN),
                            max_new_tokens=4))
    eng.run_until_drained()
    mon.vfpga_exit()
    split = eng.host_device_split()
    assert split["tokens"] == 4
    assert split["device_us_per_token"] > 0.0
