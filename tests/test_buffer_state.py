"""Property-based tests of the buffer state machine (paper §3.4).

Invariants:
  I1  DIRTY buffers always hold a device value;
  I2  after ``evict_device_state`` no buffer holds a device value and none
      is DIRTY (everything saved or reproducible);
  I3  eviction saves exactly the DIRTY bytes and skips SYNC/INIT bytes;
  I4  evict -> restore round-trips every value bit-exactly;
  I5  versions increase monotonically with device writes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                     precondition, rule)
    HAS_HYPOTHESIS = True
except ImportError:      # property tests skip; the rest of the module runs
    HAS_HYPOTHESIS = False

from repro.core.state import BufferState, BufferTable


def _spec(i):
    return jax.ShapeDtypeStruct((4, 4), jnp.float32)


if HAS_HYPOTHESIS:
    class BufferMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.table = BufferTable()
            self.counter = 0
            self.mirror = {}      # our model of what the host should hold

        @rule()
        def register(self):
            bid = f"b{self.counter}"
            self.counter += 1
            self.table.register(bid, _spec(bid))

        def _ids(self):
            return self.table.ids()

        @precondition(lambda self: self._ids())
        @rule(data=st.data())
        def h2d(self, data):
            bid = data.draw(st.sampled_from(self._ids()))
            val = np.full((4, 4), self.counter, np.float32)
            self.counter += 1
            self.table.on_h2d(bid, val, jnp.asarray(val))
            self.mirror[bid] = val

        @precondition(lambda self: any(
            self.table.get(i).device_value is not None for i in self._ids()))
        @rule(data=st.data())
        def execute_write(self, data):
            ids = [i for i in self._ids()
                   if self.table.get(i).device_value is not None]
            bid = data.draw(st.sampled_from(ids))
            val = jnp.full((4, 4), self.counter, jnp.float32)
            self.counter += 1
            old_v = self.table.get(bid).version
            self.table.on_execute_write(bid, val)
            assert self.table.get(bid).version == old_v + 1          # I5
            self.mirror[bid] = np.asarray(val)

        @precondition(lambda self: any(
            self.table.get(i).state is BufferState.DIRTY
            for i in self._ids()))
        @rule(data=st.data())
        def d2h(self, data):
            ids = [i for i in self._ids()
                   if self.table.get(i).state is BufferState.DIRTY]
            bid = data.draw(st.sampled_from(ids))
            host = self.table.on_d2h(bid)
            np.testing.assert_array_equal(np.asarray(host), self.mirror[bid])
            assert self.table.get(bid).state is BufferState.SYNC

        @rule()
        def evict_and_restore(self):
            dirty = set(self.table.dirty_ids())
            dirty_bytes = sum(self.table.get(i).nbytes for i in dirty)
            stats = self.table.evict_device_state()
            assert stats["saved_bytes"] == dirty_bytes               # I3
            assert stats["n_dirty"] == len(dirty)
            for i in self._ids():
                b = self.table.get(i)
                assert b.device_value is None                        # I2
                assert b.state is not BufferState.DIRTY
            self.table.restore_device_state()
            for i, want in self.mirror.items():
                b = self.table.get(i)
                if b.host_value is not None:
                    np.testing.assert_array_equal(                   # I4
                        np.asarray(jax.device_get(b.device_value)), want)

        @invariant()
        def dirty_implies_device(self):
            for i in self._ids():
                b = self.table.get(i)
                if b.state is BufferState.DIRTY:
                    assert b.device_value is not None                # I1

    TestBufferMachine = BufferMachine.TestCase
    TestBufferMachine.settings = settings(
        max_examples=25, stateful_step_count=30, deadline=None)
else:
    def test_buffer_machine():
        pytest.importorskip("hypothesis")


def test_snapshot_roundtrip():
    t = BufferTable()
    t.register("params", _spec("p"))
    val = np.arange(16, dtype=np.float32).reshape(4, 4)
    t.on_h2d("params", val, jnp.asarray(val))
    t.on_execute_write("params", jnp.asarray(val * 2))
    t.evict_device_state()
    snap = t.host_snapshot()
    t2 = BufferTable()
    t2.load_snapshot(snap)
    t2.restore_device_state()
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(t2.get("params").device_value)), val * 2)
