"""Launch machinery on the local 1-device mesh: build_cell lowers+compiles
for every family x step kind (full production path, toy sizes), and the HLO
collective parser handles real and synthetic inputs."""

import dataclasses

import pytest

from repro.configs import SHAPES, get_arch
from repro.launch.hlo_stats import collective_stats, collective_seconds
from repro.launch.mesh import compat_make_mesh
from repro.launch.steps import build_cell

MESH = compat_make_mesh((1, 1), ("data", "model"))

TINY = {
    "train": dataclasses.replace(SHAPES["train_4k"], seq_len=32,
                                 global_batch=4),
    "prefill": dataclasses.replace(SHAPES["prefill_32k"], seq_len=64,
                                   global_batch=2),
    "decode": dataclasses.replace(SHAPES["decode_32k"], seq_len=64,
                                  global_batch=2),
}

FAMILY_REPS = ["yi-9b-smoke", "deepseek-v3-671b-smoke", "mamba2-1.3b-smoke",
               "recurrentgemma-9b-smoke", "seamless-m4t-large-v2-smoke",
               "llava-next-mistral-7b-smoke"]


@pytest.mark.parametrize("arch", FAMILY_REPS)
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_build_cell_lowers_and_compiles(arch, kind):
    cfg = get_arch(arch)
    shape = TINY[kind]
    cell = build_cell(cfg, shape, MESH, num_microbatches=2
                      if kind == "train" else 1)
    compiled = cell.lower().compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0
    ca = compiled.cost_analysis()
    if isinstance(ca, list):         # older JAX: one entry per device
        ca = ca[0] if ca else {}
    assert ca.get("flops", 0) > 0


def test_collective_parser_synthetic():
    hlo = """
  %ag = bf16[2048,512]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs = (f32[16,16]{1,0}, f32[16,16]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[8]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %a2a = bf16[64,64]{1,0} all-to-all(%z), dimensions={1}
"""
    st = collective_stats(hlo)
    assert st["counts"] == {"all-gather": 1, "all-reduce": 1,
                            "reduce-scatter": 1, "collective-permute": 1,
                            "all-to-all": 1}
    assert st["bytes"]["all-gather"] == 2048 * 512 * 2
    assert st["bytes"]["all-reduce"] == 4096
    assert st["bytes"]["reduce-scatter"] == 2 * 16 * 16 * 4
    secs = collective_seconds(st, ici_bw=1e9)
    assert secs > 0


def test_roofline_depth_plan_all_families():
    from repro.launch.roofline import depth_plan

    for arch in FAMILY_REPS + ["qwen3-8b-smoke"]:
        cfg = get_arch(arch)
        probes, units, solve, base = depth_plan(cfg)
        assert probes and units
        for u in units:
            assert u in solve


def test_mesh_factories():
    # NOTE: cannot build 256/512-device meshes here (1 CPU device) — the
    # production meshes are exercised by launch/dryrun.py; here we check the
    # local factory only.
    from repro.launch.mesh import make_local_mesh

    m = make_local_mesh()
    assert set(m.axis_names) == {"data", "model"}
