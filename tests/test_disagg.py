"""Prefill/decode disaggregation: live KV handoff between role replicas,
TTFT-aware admission with aggregated fallback, role-aware routing and
placement, per-role autoscaling, torn-transfer replay through router
leases, and the PR 9 residuals (on-device stop-token detection, deferred
prefix-hit admission) — all gated on bit-exactness vs the aggregated
engine."""

import numpy as np
import pytest

from repro.chaos.faults import FaultPlan, FaultSpec
from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.scaling.metrics import MetricsRegistry
from repro.scaling.serving import RequestRouter
from repro.serve.disagg import (M_HANDOFF, M_HANDOFF_FALLBACK,
                                M_TRANSFER_BYTES, TransferQueue)
from repro.serve.engine import ContinuousBatchingEngine, ServeRequest

ARCH = "yi-9b-smoke"
PROMPT_LEN = 8
PAGE = 4
SPEC = [3, 6, 4, 5]


def make_engine(reg, engine_id, slots=2, max_new=8, **kw):
    mon = Monitor(engine_id, SliceAllocator("n0", 1), telemetry=reg)
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=slots,
                                   prompt_len=PROMPT_LEN,
                                   max_new_tokens=max_new, registry=reg,
                                   page_size=PAGE, engine_id=engine_id,
                                   **kw)
    eng.setup()
    return mon, eng


def make_requests(spec, seed=0):
    rng = np.random.Generator(np.random.Philox(seed))
    return [ServeRequest(rid=f"r{i}",
                         prompt=rng.integers(0, 100, PROMPT_LEN),
                         max_new_tokens=n)
            for i, n in enumerate(spec)]


def aggregated_ref(spec, seed):
    reg = MetricsRegistry()
    mon, eng = make_engine(reg, "agg")
    for r in make_requests(spec, seed=seed):
        eng.submit(r)
    eng.run_until_drained()
    ref = {rid: list(rec.tokens) for rid, rec in eng.completed.items()}
    mon.vfpga_exit()
    return ref


def run_disagg(spec, seed, *, decode_kw=None, ttft_target_s=None,
               chaos=None, step_hook=None, max_pumps=600):
    """Drive a prefill + decode replica pair over a workload through a
    RequestRouter and TransferQueue; returns (transcripts, queue,
    registry, router)."""
    reg = MetricsRegistry()
    router = RequestRouter("svc", registry=reg, kv_aware=False)
    monP, engP = make_engine(reg, "pf", role="prefill")
    monD, engD = make_engine(reg, "dec", role="decode", fuse_steps=2,
                             async_depth=1, **(decode_kw or {}))
    tq = TransferQueue(router=router, registry=reg, service="svc",
                      ttft_target_s=ttft_target_s, chaos=chaos)
    engP.attach_transfer(tq)
    engD.attach_transfer(tq)
    for r in make_requests(spec, seed=seed):
        router.submit(r)
    try:
        for i in range(max_pumps):
            if step_hook is not None:
                step_hook(engP, monP, engD, monD, i)
            engP.pump(router)
            engD.pump(router)
            if (not router.outstanding() and engP.idle and engD.idle
                    and len(tq) == 0):
                break
        else:
            raise AssertionError(
                f"disagg pair did not drain: outstanding="
                f"{router.outstanding()} queue={len(tq)}")
        got = {rid: list(rec.tokens)
               for rid, rec in router.completed.items()}
        return got, tq, reg, router
    finally:
        monP.vfpga_exit()
        monD.vfpga_exit()


# ---------------------------------------------------------------------------
# Live KV handoff: bit-exactness and fallback
# ---------------------------------------------------------------------------
def test_handoff_bit_exact_vs_aggregated():
    """Every request prefills on one replica and decodes on the other;
    the token streams equal the aggregated single-engine run."""
    ref = aggregated_ref(SPEC, seed=3)
    got, tq, reg, _ = run_disagg(SPEC, seed=3)
    assert got == ref
    snap = reg.snapshot()
    handoffs = snap["counters"][f"{M_HANDOFF}{{service=svc}}"]
    assert handoffs >= 1          # slot-aware admission may refuse some
    assert snap["counters"][f"{M_TRANSFER_BYTES}{{service=svc}}"] > 0
    # every handoff happened mid-decode: the importer continued the lane
    events = [e[1] for e in reg.flight_record()["events"]]
    assert events.count("engine_handoff_out") == handoffs
    assert events.count("engine_handoff_in") == handoffs


def test_fallback_when_decode_side_saturated():
    """A decode pool with room for ~one lane forces refusals: refused
    lanes decode to completion on the prefill replica (aggregated
    fallback) and the streams stay bit-exact."""
    ref = aggregated_ref(SPEC, seed=3)
    got, tq, reg, _ = run_disagg(SPEC, seed=3,
                                 decode_kw={"pool_pages": 5,
                                            "reserve_pages": 1})
    assert got == ref
    snap = reg.snapshot()
    assert snap["counters"][f"{M_HANDOFF_FALLBACK}{{service=svc}}"] > 0


def test_ttft_target_refuses_slow_transfers():
    """With a TTFT target below the predicted queue wait the queue
    refuses every offer — pure aggregated fallback, zero handoffs."""
    ref = aggregated_ref(SPEC, seed=3)

    def poison(engP, monP, engD, monD, i):
        # pretend installs are ruinously slow (predicted wait >> target)
        engP.transfer._ewma_install_s = 10.0

    got, tq, reg, _ = run_disagg(SPEC, seed=3, ttft_target_s=1e-9,
                                 step_hook=poison)
    assert got == ref
    snap = reg.snapshot()
    assert snap["counters"][f"{M_HANDOFF}{{service=svc}}"] == 0
    assert snap["counters"][f"{M_HANDOFF_FALLBACK}{{service=svc}}"] > 0


def test_handoff_with_evict_resume_both_sides():
    """Monitor-level evict/resume on both replicas mid-handoff traffic:
    lanes in transit and installed lanes continue bit-exactly."""
    ref = aggregated_ref(SPEC, seed=3)

    def hook(engP, monP, engD, monD, i):
        if i % 3:
            return
        for eng, mon in ((engP, monP), (engD, monD)):
            if eng.active_count:
                mon.evict()
                mon.resume()

    got, tq, reg, _ = run_disagg(SPEC, seed=3, step_hook=hook)
    assert got == ref
    assert reg.snapshot()["counters"][f"{M_HANDOFF}{{service=svc}}"] > 0


def test_handoff_then_oom_preempt_on_receiver():
    """The decode replica's pool is large enough to admit transfers but
    too small to decode every lane to its limit: imported lanes are
    OOM-preempted, recompute locally (full prefill on the decode
    replica), and the stream — including TTFT observed exactly once per
    request — stays bit-exact."""
    spec = [8, 8, 8, 8]
    ref = aggregated_ref(spec, seed=9)
    got, tq, reg, _ = run_disagg(spec, seed=9,
                                 decode_kw={"pool_pages": 7,
                                            "reserve_pages": 1})
    assert got == ref
    snap = reg.snapshot()
    assert snap["counters"][
        "engine_oom_preemptions_total{service=svc}"] > 0
    # TTFT is observed once per request across admit + handoff + recompute
    assert (snap["histograms"]["request_ttft_seconds{service=svc}"]["count"]
            == len(spec))


# ---------------------------------------------------------------------------
# Torn transfers: chaos site kv.transfer + router lease replay
# ---------------------------------------------------------------------------
def test_torn_transfer_replays_without_loss_or_duplication():
    """A transfer torn between dequeue and install loses the lane (the
    source already released it); the request replays through its router
    lease and the recompute reproduces the committed prefix — zero lost,
    zero duplicated tokens."""
    ref = aggregated_ref(SPEC, seed=3)
    plan = FaultPlan([FaultSpec(site="kv.transfer", kind="torn", at=2)])
    got, tq, reg, router = run_disagg(SPEC, seed=3, chaos=plan)
    assert tq.torn == 1
    assert got == ref                        # nothing lost
    assert len(router.completed) == len(SPEC)  # nothing duplicated
    events = [e[1] for e in reg.flight_record()["events"]]
    assert "kv_transfer_torn" in events
    assert "router_replay" in events
    # the replay's recompute reproduced the pre-tear tokens as a prefix
    assert "replay_mismatch" not in events


def test_transfer_delay_fault_is_benign():
    """kind=delay at the transfer site only stretches the install."""
    ref = aggregated_ref(SPEC, seed=3)
    plan = FaultPlan([FaultSpec(site="kv.transfer", kind="delay",
                                delay_s=0.002, at=1)])
    got, tq, _, _ = run_disagg(SPEC, seed=3, chaos=plan)
    assert got == ref and tq.torn == 0


def test_transfer_counters_export_in_prometheus_text():
    """The disaggregation counters appear in the Prometheus exposition
    (even before traffic) with finite values."""
    reg = MetricsRegistry()
    TransferQueue(registry=reg, service="svc")
    text = reg.to_prometheus_text()
    for name in (M_HANDOFF, M_HANDOFF_FALLBACK, M_TRANSFER_BYTES):
        line = next(ln for ln in text.splitlines()
                    if ln.startswith(name))
        assert np.isfinite(float(line.rsplit(" ", 1)[1]))


# ---------------------------------------------------------------------------
# Role-aware routing / leases
# ---------------------------------------------------------------------------
def test_router_never_feeds_decode_replicas():
    router = RequestRouter("svc", kv_aware=False)
    router.register_engine_role("dec", "decode", (PROMPT_LEN,))
    router.register_engine_role("pf", "prefill", (PROMPT_LEN,))
    for r in make_requests([2, 2], seed=1):
        router.submit(r)
    assert router.pop(2, engine_id="dec") == []
    assert [r.rid for r in router.pop(2, engine_id="pf")] == ["r0", "r1"]


def test_bucketed_prompt_routing_between_prefills():
    """Two prefill replicas with buckets (4, 8): a short prompt maps to
    the first replica, a long one to the second — and deferral is a head
    start, never starvation."""
    router = RequestRouter("svc", kv_aware=False)
    router.register_engine_role("pfA", "prefill", (4, 8))
    router.register_engine_role("pfB", "prefill", (4, 8))
    rng = np.random.Generator(np.random.Philox(2))
    router.submit(ServeRequest(rid="short", prompt=rng.integers(0, 100, 3),
                               max_new_tokens=2))
    router.submit(ServeRequest(rid="long", prompt=rng.integers(0, 100, 8),
                               max_new_tokens=2))
    # head is `short` (bucket idx 0 -> pfA): pfB is held back once
    assert router.pop(1, engine_id="pfB") == []
    assert [r.rid for r in router.pop(1, engine_id="pfA")] == ["short"]
    # head is `long` (bucket idx 1 -> pfB)
    assert router.pop(1, engine_id="pfA") == []
    assert [r.rid for r in router.pop(1, engine_id="pfB")] == ["long"]


def test_transfer_lease_moves_crash_replay_ownership():
    """After a handoff the lease points at the decode replica: its crash
    replays the request; the old owner's crash no longer does."""
    router = RequestRouter("svc", kv_aware=False)
    for r in make_requests([2], seed=4):
        router.submit(r)
    (req,) = router.pop(1, engine_id="pf")
    req.committed = [7]
    router.transfer_lease(req.rid, "dec")
    assert router.fail_engine("pf") == 0     # no longer the owner
    assert router.fail_engine("dec") == 1
    assert router.replayed[req.rid] == [7]


# ---------------------------------------------------------------------------
# Role-aware placement and per-role autoscaling
# ---------------------------------------------------------------------------
class _View:
    def __init__(self, capacity):
        self.capacity = dict(capacity)

    def nodes(self):
        return list(self.capacity)

    def free_slices(self, node):
        return self.capacity[node]

    def running_tasks(self, node):
        return []


def test_placement_scores_roles():
    """Decode tasks steer toward the node advertising the most free KV
    pages (at equal capacity); prefill tasks get an extra free-compute
    bonus on top of the capacity term."""
    from repro.core.placement import M_NODE_KV_FREE, PlacementPolicy
    from repro.core.scheduler import SchedTask

    reg = MetricsRegistry()
    # name tie-break alone would pick "b"; the KV gauge flips it to "a"
    reg.gauge(M_NODE_KV_FREE, node="a").set(64)
    reg.gauge(M_NODE_KV_FREE, node="b").set(4)
    pol = PlacementPolicy(registry=reg)
    view = _View({"a": 2, "b": 2})
    dec = SchedTask(tid="dec", meta={"role": "decode"})
    plain = SchedTask(tid="t", meta={})
    assert pol.select_node(plain, view, {}) == "b"
    assert pol.select_node(dec, view, {}) == "a"
    # prefill: the free-compute bonus scales with free slices
    pf = SchedTask(tid="pf", meta={"role": "prefill"})
    w = pol.weights
    assert (pol.score(pf, "a", view, 3) - pol.score(plain, "a", view, 3)
            == pytest.approx(w.role_compute * 3))


def test_role_mix_policy_scales_and_fits_budget():
    from repro.scaling.autoscaler import RoleMixPolicy, ScalingSignals

    pol = RoleMixPolicy(slice_budget=8, vfpga_num=2)
    idle = pol.desired_mix(ScalingSignals(replicas=2))
    assert (idle.prefill, idle.decode) == (1, 1)
    assert idle.total_slices <= 8

    # queue depth grows the prefill side
    queued = pol.desired_mix(ScalingSignals(replicas=2, queue_depth=6.0))
    assert queued.prefill > idle.prefill
    assert queued.total_slices <= 8

    # KV pressure grows the decode side
    hot = pol.desired_mix(ScalingSignals(replicas=2, kv_pressure=0.95))
    assert hot.decode > idle.decode
    assert hot.total_slices <= 8

    # scarce slices: vertical size is shed before replicas, floors hold
    tight = RoleMixPolicy(slice_budget=3, vfpga_num=2)
    mix = tight.desired_mix(ScalingSignals(replicas=2, queue_depth=8.0,
                                           kv_pressure=0.95))
    assert mix.total_slices <= 3
    assert mix.prefill >= 1 and mix.decode >= 1
    assert min(mix.prefill_vfpga, mix.decode_vfpga) == 1


def test_disaggregated_service_model_bounds():
    from repro.core.simulator import (disaggregated_service_model,
                                      engine_service_model)
    from repro.scaling.loadgen import Request

    req = Request(rid="r", arrival_t=0.0, service_s=0.0, n_tokens=8)
    agg = engine_service_model(0.05, 0.002)
    # full fallback degrades exactly to the aggregated model, never worse
    full_fb = disaggregated_service_model(0.05, 0.002, fallback_rate=1.0)
    assert full_fb(req) == pytest.approx(agg(req))
    # a clean handoff holds the decode pool for transfer + tail only
    clean = disaggregated_service_model(0.05, 0.002, transfer_s=0.001)
    assert clean(req) < agg(req)


# ---------------------------------------------------------------------------
# Engine role/eos validation
# ---------------------------------------------------------------------------
def test_role_and_eos_config_validation():
    from repro.serve.engine import SpecConfig

    reg = MetricsRegistry()
    mon = Monitor("cfg", SliceAllocator("n0", 1), telemetry=reg)
    cl = FunkyCL(mon)
    mk = lambda **kw: ContinuousBatchingEngine(
        ARCH, cl, slots=2, prompt_len=PROMPT_LEN, max_new_tokens=4,
        registry=reg, page_size=PAGE, **kw)
    with pytest.raises(ValueError):
        mk(role="verifier")
    with pytest.raises(ValueError):
        mk(role="prefill", paged=False)
    with pytest.raises(ValueError):
        mk(role="decode", spec=SpecConfig(k=2))
    with pytest.raises(ValueError):
        mk(eos_id=5, spec=SpecConfig(k=2))
    with pytest.raises(ValueError):
        mk(role="mixed").attach_transfer(TransferQueue())
    mon.vfpga_exit()


# ---------------------------------------------------------------------------
# PR 9 residuals: on-device EOS, deferred prefix-hit admission
# ---------------------------------------------------------------------------
def _eos_token(spec, seed):
    """Pick a token the reference streams emit mid-sequence, so EOS
    genuinely truncates at least one request."""
    ref = aggregated_ref(spec, seed)
    for toks in ref.values():
        if len(toks) > 2:
            return ref, int(toks[1])
    raise AssertionError("no stream long enough to pick an EOS token")


def _truncate_at(ref, eos):
    out = {}
    for rid, toks in ref.items():
        cut = toks.index(eos) + 1 if eos in toks else len(toks)
        out[rid] = toks[:cut]
    return out


@pytest.mark.parametrize("fused_kw", [
    {"fuse_steps": 4, "async_depth": 1},    # on-device freeze mid-span
    {"fuse_steps": 1, "async_depth": 2},    # host-side EOS, async commits
])
def test_on_device_eos_bit_exact_vs_host_side(fused_kw):
    """A lane emitting eos_id freezes inside decode_multi (or is cut at
    the async commit): tokens match the synchronous host-side EOS engine
    exactly, including the stop token itself."""
    ref, eos = _eos_token(SPEC, seed=3)
    want = _truncate_at(ref, eos)
    assert any(len(v) < len(ref[k]) for k, v in want.items())

    for tag, kw in (("sync", {}), ("pipelined", fused_kw)):
        reg = MetricsRegistry()
        mon, eng = make_engine(reg, f"eos-{tag}", eos_id=eos, **kw)
        for r in make_requests(SPEC, seed=3):
            eng.submit(r)
        eng.run_until_drained()
        got = {rid: list(rec.tokens) for rid, rec in eng.completed.items()}
        mon.vfpga_exit()
        assert got == want, f"eos mismatch on {tag} engine"


def test_eos_mid_span_with_evict_resume():
    """EOS freeze inside a fused span survives monitor evict/resume."""
    from repro.serve.equivalence import evict_resume_every, run_transcript

    ref, eos = _eos_token(SPEC, seed=3)
    want = _truncate_at(ref, eos)

    def factory():
        mon, eng = make_engine(MetricsRegistry(), "eos-ev", eos_id=eos,
                               fuse_steps=4, async_depth=1)
        return mon, eng

    got, _ = run_transcript(factory,
                            lambda: make_requests(SPEC, seed=3),
                            step_hook=evict_resume_every(3))
    assert got == want


def test_deferred_prefix_hit_admission_bit_exact():
    """Prefix-hit suffix prefills ride the async pipeline (first-token
    read deferred, tree insert parked): repeat-prompt waves on a
    pipelined prefix-cache engine match the synchronous one."""
    from repro.serve.equivalence import check_equivalence

    def factory(**kw):
        def make():
            mon, eng = make_engine(MetricsRegistry(), "px",
                                   prefix_cache=True, **kw)
            return mon, eng
        return make

    def requests():
        # two waves over three distinct prompts: wave 2 hits the tree
        reqs = make_requests([4, 6, 3], seed=17)
        rep = make_requests([5, 4, 6], seed=17)
        for r in rep:
            r.rid = "w2-" + r.rid
        return reqs + rep

    eng, _ = check_equivalence(
        factory(fuse_steps=2, async_depth=1), factory(), requests,
        context="deferred prefix admission")
    assert eng.prefix_hits + eng.prefix_partial_hits > 0
