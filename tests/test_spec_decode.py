"""Speculative decode on the paged engine: bit-exact transcript equivalence
vs non-speculative greedy decode across acceptance regimes (forced-accept
self-draft, forced-reject antigreedy draft, mixed different-seed draft),
rollback freeing exactly the orphaned lookahead tail, evict/resume with a
lane mid-lookahead, OOM preemption during lookahead, prompt buckets, and
the acceptance-rate gauge — all through the reusable equivalence harness
in ``repro.serve.equivalence``."""

import numpy as np
import pytest

from repro.core import FunkyCL, Monitor, SliceAllocator
from repro.scaling.autoscaler import M_SPEC_ACCEPT_RATE
from repro.scaling.metrics import MetricsRegistry
from repro.serve.engine import (ContinuousBatchingEngine, ServeRequest,
                                SpecConfig)
from repro.serve.equivalence import (assert_transcripts_equal,
                                     check_equivalence, evict_resume_every,
                                     run_transcript)

ARCH = "yi-9b-smoke"
PROMPT_LEN = 8
PAGE = 4
SPEC = [3, 6, 4, 5]            # ragged per-request generation lengths


def factory(spec=None, slots=2, max_new=8, **kw):
    def make():
        reg = MetricsRegistry()
        mon = Monitor("spec-test", SliceAllocator("n0", 1), telemetry=reg)
        eng = ContinuousBatchingEngine(
            ARCH, FunkyCL(mon), slots=slots, prompt_len=PROMPT_LEN,
            max_new_tokens=max_new, registry=reg, page_size=PAGE,
            spec=spec, **kw)
        eng.setup()
        return mon, eng
    return make


def requests(spec_list=SPEC, seed=3, prompt_len=PROMPT_LEN):
    def make():
        rng = np.random.Generator(np.random.Philox(seed))
        return [ServeRequest(rid=f"r{i}",
                             prompt=rng.integers(0, 100, prompt_len),
                             max_new_tokens=n)
                for i, n in enumerate(spec_list)]
    return make


@pytest.fixture(scope="module")
def plain_ref():
    """Non-speculative paged greedy transcript for SPEC."""
    ref, _ = run_transcript(factory(), requests())
    return ref


def test_forced_accept_bit_exact_and_multitoken(plain_ref):
    """Self-draft (same arch + seed => identical params): every draft token
    is accepted, so iterations commit up to k+1 tokens — and the stream is
    still bit-exact vs plain greedy decode."""
    got, eng = run_transcript(factory(SpecConfig(k=2)), requests())
    assert_transcripts_equal(got, plain_ref, context="forced-accept")
    stats = eng.spec_stats()
    assert stats["accept_rate"] == 1.0
    assert stats["tokens_per_lane_iteration"] > 1
    assert stats["committed_tokens"] == sum(SPEC) - len(SPEC)


def test_forced_reject_bit_exact(plain_ref):
    """Antigreedy draft (argmin) mismatches at every position: each
    iteration commits exactly the target's own token — plain-decode
    throughput, identical stream, and every lookahead tail rolled back."""
    got, eng = run_transcript(
        factory(SpecConfig(k=2, draft_mode="antigreedy")), requests())
    assert_transcripts_equal(got, plain_ref, context="forced-reject")
    stats = eng.spec_stats()
    assert stats["accept_rate"] == 0.0
    assert stats["tokens_per_lane_iteration"] == 1.0


def test_mixed_draft_bit_exact(plain_ref):
    """A different-seed draft has arbitrary (mostly rejecting) agreement;
    the committed stream must not depend on the draft at all."""
    got, eng = run_transcript(
        factory(SpecConfig(k=2, draft_seed=99)), requests())
    assert_transcripts_equal(got, plain_ref, context="mixed")
    assert 0.0 <= eng.spec_stats()["accept_rate"] <= 1.0


def test_spec_vs_dense_reserved_baseline():
    """The harness is baseline-parameterized: spec-paged vs the worst-case
    reserved (non-paged) engine."""
    check_equivalence(factory(SpecConfig(k=2)), factory(paged=False),
                      requests(), context="spec-vs-dense")


def test_rollback_frees_exactly_orphaned_tail():
    """Rejected lookaheads free only the pages wholly past the committed
    prefix: the pool invariant checker holds after every iteration, pages
    drain to zero, and rollback events record freed tails."""
    def hook(eng, mon, i):
        eng.pool.check_invariants()
        for st in eng._active.values():
            # tail-free invariant: a lane holds exactly the pages that
            # cover its committed history, never a stale lookahead tail
            assert len(st.blocks) == -(-st.pos // PAGE)
    got, eng = run_transcript(
        factory(SpecConfig(k=3, draft_mode="antigreedy")), requests(),
        step_hook=hook)
    assert eng.pool.used_count() == 0
    rollbacks = [e for e in eng.registry.flight_record()["events"]
                 if e[1] == "engine_spec_rollback"]
    assert rollbacks and all(e[2]["freed"] > 0 for e in rollbacks)


def test_evict_resume_mid_lookahead_bit_exact(plain_ref):
    """Evict/resume between iterations while kept pages still hold
    rejected lookahead writes: the dirty-page report covers the partially
    accepted pages, so the resumed lanes continue bit-exactly."""
    got, _ = run_transcript(
        factory(SpecConfig(k=2, draft_mode="antigreedy")), requests(),
        step_hook=evict_resume_every(1))
    assert_transcripts_equal(got, plain_ref, context="evict-mid-lookahead")
    got, eng = run_transcript(factory(SpecConfig(k=3)), requests(),
                              step_hook=evict_resume_every(2))
    assert_transcripts_equal(got, plain_ref, context="evict-k3")
    assert eng.spec_stats()["accept_rate"] == 1.0


def test_oom_preemption_during_lookahead_recomputes_bit_exact(plain_ref):
    """A pool too small for every lane's lookahead span forces OOM
    preemption mid-lookahead; the victim requeues and recomputes the
    identical greedy stream."""
    got, eng = run_transcript(
        factory(SpecConfig(k=2), pool_pages=6, reserve_pages=1), requests())
    assert_transcripts_equal(got, plain_ref, context="oom-lookahead")
    assert eng.preemptions > 0
    eng.pool.check_invariants()


def test_spec_with_prompt_buckets(plain_ref):
    """Speculation composes with bucketed prefill (per-bucket draft
    prefill/admit programs)."""
    got, eng = run_transcript(
        factory(SpecConfig(k=2), prompt_buckets=(4, PROMPT_LEN)),
        requests())
    assert_transcripts_equal(got, plain_ref, context="buckets")
    assert eng.spec_stats()["accept_rate"] == 1.0


def test_accept_rate_gauge_published_and_tombstoned_on_kill():
    """The per-engine acceptance gauge lands in the registry under the
    canonical name (the drive loop folds it to a service-level mean); a
    killed replica tombstones it with NaN so dead engines stop biasing
    the service mean."""
    import math

    _, eng = run_transcript(factory(SpecConfig(k=2)), requests())
    vals = eng.registry.labeled_gauge_values(M_SPEC_ACCEPT_RATE,
                                             service="svc")
    per_engine = {lbl["engine"]: v for lbl, v in vals if "engine" in lbl}
    assert per_engine == {"engine0": 1.0}
    eng.evacuate()                         # kill path
    vals = eng.registry.labeled_gauge_values(M_SPEC_ACCEPT_RATE,
                                             service="svc")
    assert all(math.isnan(v) for lbl, v in vals if "engine" in lbl)


def test_dynamic_k_shrinks_on_rejection(plain_ref):
    """A forced-reject draft drives the windowed acceptance to zero, so a
    dynamic engine shrinks its lookahead to ``k_min`` (rejected verify work
    stops burning iterations) — with a committed stream still bit-exact vs
    plain greedy decode."""
    got, eng = run_transcript(
        factory(SpecConfig(k=2, draft_mode="antigreedy", dynamic_k=True,
                           adapt_window=4)),
        requests())
    assert_transcripts_equal(got, plain_ref, context="dynamic-shrink")
    assert eng.spec_k_now == 1
    adapts = [e for e in eng.registry.flight_record()["events"]
              if e[1] == "engine_spec_k_adapt"]
    assert adapts and adapts[-1][2]["k_to"] == 1
    # the live-k gauge tracks the adaptation
    from repro.serve.engine import M_SPEC_K
    vals = {lbl["engine"]: v for lbl, v in
            eng.registry.labeled_gauge_values(M_SPEC_K, service="svc")
            if "engine" in lbl}
    assert vals == {"engine0": 1.0}


def test_dynamic_k_regrows_on_sustained_acceptance():
    """Starting from a shrunk lookahead, a forced-accept (self-draft)
    workload regrows k to the configured maximum after two consecutive
    high-acceptance windows — and the whole adaptive run stays bit-exact
    vs the plain (non-speculative) engine."""
    def shrunk_factory():
        mon, eng = factory(SpecConfig(k=2, dynamic_k=True,
                                      adapt_window=4))()
        eng.spec_k_now = 1          # as if a bad phase shrank the lookahead
        return mon, eng

    # longer generations so enough windows elapse for the regrow streak
    reqs = requests(spec_list=[8, 8, 7, 8])
    eng, _ = check_equivalence(shrunk_factory, factory(), reqs,
                               context="dynamic-regrow")
    assert eng.spec_k_now == 2
    assert eng.spec_stats()["k_now"] == 2


def test_spec_requires_paged_mode():
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(
            ARCH, FunkyCL(Monitor("x", SliceAllocator("n0", 1))),
            paged=False, spec=SpecConfig(k=2))


def test_harness_reports_first_divergence():
    """The equivalence harness itself: a corrupted transcript fails with a
    diagnostic naming the request and token position."""
    ref = {"r0": [1, 2, 3]}
    with pytest.raises(AssertionError, match="rid=r0 at token 1"):
        assert_transcripts_equal({"r0": [1, 9, 3]}, ref)
    with pytest.raises(AssertionError, match="request sets differ"):
        assert_transcripts_equal({}, ref)


def test_spec_rides_delta_block_table(plain_ref):
    """Spec decode's per-depth programs run over the same device-resident
    block table: steady-state updates go through delta EXECUTEs (rollback
    cells included), never full host rewrites."""
    got, eng = run_transcript(factory(spec=SpecConfig(k=2, draft_seed=99)),
                              requests())
    assert_transcripts_equal(got, plain_ref, context="spec + delta bt")
    assert eng.bt_delta_execs > 0
    assert eng.bt_full_writes == 0


def test_spec_refuses_fused_pipeline():
    """Verify already fuses k+1 positions and acceptance is host-decided:
    combining it with fused/pipelined decode is a config error."""
    with pytest.raises(ValueError):
        factory(spec=SpecConfig(k=2), fuse_steps=4)()
    with pytest.raises(ValueError):
        factory(spec=SpecConfig(k=2), async_depth=1)()
