"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real single
device; only launch/dryrun.py forces 512 host devices."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model

_BUNDLES = {}
_PARAMS = {}


def bundle_for(arch: str, **kw):
    key = (arch, tuple(sorted(kw.items())))
    if key not in _BUNDLES:
        _BUNDLES[key] = build_model(get_arch(arch), **kw)
    return _BUNDLES[key]


def params_for(arch: str, **kw):
    key = (arch, tuple(sorted(kw.items())))
    if key not in _PARAMS:
        _PARAMS[key] = bundle_for(arch, **kw).init(jax.random.PRNGKey(0))
    return _PARAMS[key]


def tiny_batch(cfg, B=2, S=32, seed=0):
    import numpy as np

    rng = np.random.Generator(np.random.Philox(seed))
    toks = lambda *s: rng.integers(0, cfg.vocab_size, s).astype("int32")
    if cfg.family == "encdec":
        T = max(int(S * cfg.tgt_ratio), 8)
        return {"src_emb": jnp.asarray(
                    rng.standard_normal((B, S, cfg.d_model), dtype="float32") * 0.02),
                "tgt_tokens": jnp.asarray(toks(B, T)),
                "tgt_targets": jnp.asarray(toks(B, T))}
    if cfg.family == "vlm":
        return {"tokens": jnp.asarray(toks(B, S)),
                "targets": jnp.asarray(toks(B, S)),
                "img_emb": jnp.asarray(
                    rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model),
                                        dtype="float32") * 0.02)}
    return {"tokens": jnp.asarray(toks(B, S)),
            "targets": jnp.asarray(toks(B, S))}


@pytest.fixture(scope="session")
def all_smoke_archs():
    return [f"{name}-smoke" for name in ARCHS]
