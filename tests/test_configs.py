import pytest

from repro.configs import (ARCHS, SHAPES, all_cells, applicable, get_arch,
                           get_shape, reduced)


def test_ten_archs_four_shapes():
    assert len(ARCHS) == 10
    assert len(SHAPES) == 4
    assert len(all_cells()) == 40


def test_exact_dims_match_assignment():
    a = ARCHS
    assert (a["recurrentgemma-9b"].num_layers, a["recurrentgemma-9b"].d_model,
            a["recurrentgemma-9b"].num_heads, a["recurrentgemma-9b"].num_kv_heads,
            a["recurrentgemma-9b"].d_ff, a["recurrentgemma-9b"].vocab_size) == \
        (38, 4096, 16, 1, 12288, 256000)
    assert (a["yi-9b"].num_layers, a["yi-9b"].d_model, a["yi-9b"].num_heads,
            a["yi-9b"].num_kv_heads, a["yi-9b"].d_ff, a["yi-9b"].vocab_size) == \
        (48, 4096, 32, 4, 11008, 64000)
    assert (a["stablelm-3b"].num_layers, a["stablelm-3b"].d_model,
            a["stablelm-3b"].d_ff, a["stablelm-3b"].vocab_size) == \
        (32, 2560, 6912, 50304)
    assert (a["qwen3-8b"].num_layers, a["qwen3-8b"].d_model,
            a["qwen3-8b"].num_kv_heads, a["qwen3-8b"].vocab_size) == \
        (36, 4096, 8, 151936)
    assert a["qwen3-8b"].qk_norm
    assert (a["starcoder2-15b"].num_layers, a["starcoder2-15b"].d_model,
            a["starcoder2-15b"].num_heads, a["starcoder2-15b"].d_ff) == \
        (40, 6144, 48, 24576)
    assert (a["llava-next-mistral-7b"].d_ff,
            a["llava-next-mistral-7b"].vocab_size) == (14336, 32000)
    assert a["llava-next-mistral-7b"].num_image_tokens > 0
    ds = a["deepseek-v3-671b"]
    assert (ds.num_layers, ds.d_model, ds.num_heads, ds.vocab_size) == \
        (61, 7168, 128, 129280)
    assert (ds.moe.num_experts, ds.moe.top_k, ds.moe.num_shared_experts,
            ds.moe.d_ff) == (256, 8, 1, 2048)
    assert (ds.mla.kv_lora_rank, ds.mla.q_lora_rank,
            ds.mla.qk_rope_head_dim) == (512, 1536, 64)
    dm = a["deepseek-moe-16b"]
    assert (dm.num_layers, dm.d_model, dm.moe.num_experts, dm.moe.top_k,
            dm.moe.num_shared_experts) == (28, 2048, 64, 6, 2)
    sm = a["seamless-m4t-large-v2"]
    assert (sm.encoder_layers, sm.num_layers, sm.d_model, sm.d_ff,
            sm.vocab_size) == (24, 24, 1024, 8192, 256206)
    mb = a["mamba2-1.3b"]
    assert (mb.num_layers, mb.d_model, mb.vocab_size, mb.ssm.d_state) == \
        (48, 2048, 50280, 128)


def test_shapes_match_assignment():
    s = SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
    assert s["decode_32k"].kind == "decode"
    assert s["long_500k"].kind == "decode"


def test_long_context_applicability():
    ok_archs = {a.name for a, sh, ok, _ in all_cells()
                if sh.name == "long_500k" and ok}
    assert ok_archs == {"mamba2-1.3b", "recurrentgemma-9b"}


def test_reduced_keeps_family_features():
    for name, cfg in ARCHS.items():
        r = reduced(cfg)
        assert r.family == cfg.family
        assert r.moe.enabled == cfg.moe.enabled
        assert r.mla.enabled == cfg.mla.enabled
        assert r.ssm.enabled == cfg.ssm.enabled
        assert r.rec.enabled == cfg.rec.enabled
        assert r.d_model <= 128


def test_get_arch_smoke_suffix():
    r = get_arch("yi-9b-smoke")
    assert r.d_model == 64


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_arch("gpt5")
    with pytest.raises(KeyError):
        get_shape("train_999")
