"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes and no NaNs — as required by the assignment."""

import jax
import jax.numpy as jnp
import pytest

from conftest import bundle_for, params_for, tiny_batch
from repro.configs import ARCHS, get_arch

SMOKE = [f"{n}-smoke" for n in ARCHS]


@pytest.mark.parametrize("arch", SMOKE)
def test_train_step_shapes_and_no_nans(arch):
    cfg = get_arch(arch)
    b = bundle_for(arch)
    params = params_for(arch)
    batch = tiny_batch(cfg)
    loss, metrics = jax.jit(b.loss_fn)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    grads = jax.jit(jax.grad(lambda p: b.loss_fn(p, batch)[0]))(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert not bool(jnp.isnan(g).any()), (arch, path)


@pytest.mark.parametrize("arch", SMOKE)
def test_prefill_then_decode(arch):
    cfg = get_arch(arch)
    b = bundle_for(arch)
    params = params_for(arch)
    batch = tiny_batch(cfg)
    if cfg.family == "encdec":
        pre = {"src_emb": batch["src_emb"], "tgt_tokens": batch["tgt_tokens"]}
        pos0 = batch["tgt_tokens"].shape[1]
    elif cfg.family == "vlm":
        pre = {"tokens": batch["tokens"], "img_emb": batch["img_emb"]}
        pos0 = batch["tokens"].shape[1] + cfg.num_image_tokens
    else:
        pre = {"tokens": batch["tokens"]}
        pos0 = batch["tokens"].shape[1]
    logits, caches = jax.jit(b.prefill_fn)(params, pre)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(2):
        logits, caches = jax.jit(b.decode_fn)(
            params, tok, jnp.int32(pos0 + i), caches)
        assert logits.shape == (2, cfg.vocab_size)
        assert jnp.isfinite(logits).all(), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["yi-9b-smoke", "qwen3-8b-smoke",
                                  "mamba2-1.3b-smoke",
                                  "recurrentgemma-9b-smoke",
                                  "deepseek-v3-671b-smoke"])
def test_decode_matches_fullseq_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence next-token
    logits — validates every cache implementation end-to-end."""
    import dataclasses

    from repro.models import build_model

    cfg = get_arch(arch)
    if cfg.moe.enabled:
        # capacity-based routing drops tokens batch-shape-dependently; a high
        # capacity factor makes the MoE layer exact for this equivalence test
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        b = build_model(cfg)
        params = b.init(jax.random.PRNGKey(0))
    else:
        b = bundle_for(arch)
        params = params_for(arch)
    B, S = 2, 16
    toks = (jnp.arange(B * (S + 1), dtype=jnp.int32)
            .reshape(B, S + 1) * 37) % cfg.vocab_size
    # full-seq logits at position S-1 predicts token S
    full_logits, _ = jax.jit(b.prefill_fn)(
        params, {"tokens": toks[:, : S + 1]})
    # prefill S tokens then teacher-force one decode step
    logits_p, caches = jax.jit(b.prefill_fn)(params, {"tokens": toks[:, :S]})
    logits_d, _ = jax.jit(b.decode_fn)(
        params, toks[:, S], jnp.int32(S), caches)
    a = logits_d.astype(jnp.float32)
    bq = full_logits.astype(jnp.float32)
    diff = float(jnp.max(jnp.abs(a - bq)))
    scale = float(jnp.max(jnp.abs(bq))) + 1e-6
    assert diff / scale < 0.08, (arch, diff, scale)


def test_analytic_param_count_matches_deepseek_scale():
    from repro.models import analytic_param_count

    n = analytic_param_count(ARCHS["deepseek-v3-671b"])
    assert 6.0e11 < n < 7.5e11, n      # ~671B
    n_active = analytic_param_count(ARCHS["deepseek-v3-671b"],
                                    active_only=True)
    assert 3.0e10 < n_active < 5.0e10, n_active   # ~37B active


def test_input_specs_cover_all_cells():
    from repro.configs import all_cells
    from repro.models import input_specs

    for arch, shape, ok, _ in all_cells():
        specs = input_specs(arch, shape)
        if shape.kind == "decode":
            assert "caches" in specs and "token" in specs
        else:
            assert "batch" in specs
