"""THE core state-management property (paper §3.4): a task that is evicted,
migrated, checkpointed and restored mid-run must produce results identical to
an uninterrupted run.  Valid because eviction lands on request boundaries and
the data stream is a pure function of (seed, step)."""

import time

import numpy as np
import pytest

from repro.core import TaskImage, TaskStatus, make_cluster

IMG = TaskImage(name="t", kind="train", arch="yi-9b-smoke", seq_len=16,
                global_batch=4, total_steps=10, chunks=2, seed=7)


def _final_params(runtime, cid):
    # the guest extracts its results before vfpga_exit zeroes device memory
    return runtime.tasks[cid].guest_state.user["final_params"]


def _run_uninterrupted():
    cl = make_cluster(num_nodes=1, slices_per_node=1, images={"t": IMG})
    rt = cl.nodes["node0"].runtime
    rt.create("ref", IMG)
    rt.start("ref")
    assert rt.wait("ref", timeout=600) == TaskStatus.DONE
    return _final_params(rt, "ref"), rt.tasks["ref"].guest_state


def _assert_tree_equal(a, b):
    import jax

    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def reference():
    return _run_uninterrupted()


def test_evict_resume_is_transparent(reference):
    ref_params, ref_gs = reference
    cl = make_cluster(num_nodes=1, slices_per_node=1, images={"t": IMG})
    rt = cl.nodes["node0"].runtime
    rt.create("x", IMG)
    rt.start("x")
    # evict mid-run (after setup), then resume
    while rt.tasks["x"].guest_state.step < 2 and \
            rt.status("x") not in (TaskStatus.DONE, TaskStatus.FAILED):
        time.sleep(0.01)
    if rt.status("x") == TaskStatus.RUNNING:
        rt.evict("x")
        assert rt.tasks["x"].guest_state.step < IMG.total_steps
        rt.resume("x")
    assert rt.wait("x", timeout=600) == TaskStatus.DONE
    assert rt.tasks["x"].guest_state.step == ref_gs.step
    _assert_tree_equal(_final_params(rt, "x"), ref_params)


def test_migration_is_transparent(reference):
    ref_params, _ = reference
    cl = make_cluster(num_nodes=2, slices_per_node=1, images={"t": IMG})
    rt0 = cl.nodes["node0"].runtime
    rt1 = cl.nodes["node1"].runtime
    rt0.create("x", IMG)
    rt0.start("x")
    while rt0.tasks["x"].guest_state.step < 2 and \
            rt0.status("x") not in (TaskStatus.DONE, TaskStatus.FAILED):
        time.sleep(0.01)
    if rt0.status("x") == TaskStatus.RUNNING:
        rt0.evict("x")
        rt1.resume("x", source=rt0)
        rt = rt1
    else:
        rt = rt0
    assert rt.wait("x", timeout=600) == TaskStatus.DONE
    _assert_tree_equal(_final_params(rt, "x"), ref_params)


def test_checkpoint_restore_is_transparent(reference):
    ref_params, _ = reference
    cl = make_cluster(num_nodes=2, slices_per_node=1, images={"t": IMG})
    rt0 = cl.nodes["node0"].runtime
    rt1 = cl.nodes["node1"].runtime
    rt0.create("x", IMG)
    rt0.start("x")
    while rt0.tasks["x"].guest_state.step < 2 and \
            rt0.status("x") not in (TaskStatus.DONE, TaskStatus.FAILED):
        time.sleep(0.01)
    path = rt0.checkpoint("x", keep_running=False)
    rt0.kill("x")
    rt1.restore("y", path)                 # crash-restart on another node
    assert rt1.wait("y", timeout=600) == TaskStatus.DONE
    _assert_tree_equal(_final_params(rt1, "y"), ref_params)
