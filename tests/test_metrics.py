"""Telemetry registry: quantiles, windowing, ring-buffer eviction, and
simulated-clock injection (live plane and simulator must emit one schema)."""

import math

import pytest

from repro.scaling.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                                   TimeSeries, metric_key)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_metric_key_label_ordering():
    assert metric_key("m", {}) == "m"
    assert (metric_key("m", {"b": "2", "a": "1"})
            == metric_key("m", {"a": "1", "b": "2"})
            == "m{a=1,b=2}")


def test_counter_monotonic():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_add():
    g = Gauge()
    g.set(4)
    g.add(-1.5)
    assert g.value == 2.5


def test_labeled_gauge_values_selects_by_label():
    """(label_dict, value) pairs let a KV-aware router pick the engine
    with the most free pages without parsing flattened keys."""
    from repro.scaling.metrics import MetricsRegistry

    reg = MetricsRegistry()
    reg.gauge("kv_free_pages", service="svc", engine="e0").set(10.0)
    reg.gauge("kv_free_pages", service="svc", engine="e1").set(3.0)
    reg.gauge("kv_free_pages", service="other", engine="e2").set(99.0)
    reg.gauge("kv_free_pages", service="svc").set(10.0)   # service rollup
    got = reg.labeled_gauge_values("kv_free_pages", service="svc")
    per_engine = {lbl["engine"]: v for lbl, v in got if "engine" in lbl}
    assert per_engine == {"e0": 10.0, "e1": 3.0}
    assert max(per_engine, key=per_engine.get) == "e0"


def test_histogram_quantiles():
    clock = FakeClock()
    h = Histogram(clock, window_s=60.0)
    for v in range(1, 101):          # 1..100
        h.observe(float(v))
    assert h.count == 100
    assert abs(h.quantile(0.50) - 50.5) < 1e-9
    assert abs(h.quantile(0.95) - 95.05) < 1e-9
    assert abs(h.quantile(0.99) - 99.01) < 1e-9
    s = h.summary()
    assert s["max"] == 100.0 and s["window_count"] == 100


def test_histogram_window_eviction_keeps_cumulative():
    clock = FakeClock()
    h = Histogram(clock, window_s=10.0)
    h.observe(1000.0)                # at t=0
    clock.t = 5.0
    h.observe(1.0)
    clock.t = 11.0                   # first sample now out of window
    h.observe(2.0)
    assert sorted(h.window_values()) == [1.0, 2.0]
    assert h.count == 3              # cumulative survives eviction
    assert h.sum == 1003.0
    clock.t = 100.0
    assert h.window_values() == []
    assert math.isnan(h.quantile(0.5))


def test_histogram_bounded_memory():
    clock = FakeClock()
    h = Histogram(clock, window_s=float("inf"), max_samples=16)
    for v in range(100):
        h.observe(float(v))
    assert len(h.window_values()) == 16          # ring kept newest
    assert min(h.window_values()) == 84.0
    assert h.count == 100


def test_timeseries_ring_eviction():
    clock = FakeClock()
    ts = TimeSeries(clock, capacity=4)
    for i in range(10):
        clock.t = float(i)
        ts.record(i * 10.0)
    assert len(ts) == 4
    assert ts.points() == [(6.0, 60.0), (7.0, 70.0), (8.0, 80.0),
                           (9.0, 90.0)]
    assert ts.window(7.0, 8.5) == [(7.0, 70.0), (8.0, 80.0)]


def test_timeseries_time_weighted_mean():
    clock = FakeClock()
    ts = TimeSeries(clock, capacity=16)
    ts.record(2.0, t=0.0)
    ts.record(4.0, t=10.0)           # 2 held for 10s
    ts.record(4.0, t=20.0)           # 4 held for 10s
    assert abs(ts.time_weighted_mean() - 3.0) < 1e-9


def test_histogram_window_override_is_order_independent():
    """A reader that merely gets the histogram first (signals path) must
    not pin the window; the writer's explicit window_s always wins."""
    clock = FakeClock()
    reg = MetricsRegistry(clock=clock)
    reader = reg.histogram("request_latency_seconds", service="svc")
    assert reader.window_s == 60.0                 # default on create
    writer = reg.histogram("request_latency_seconds", window_s=10.0,
                           service="svc")
    assert writer is reader and reader.window_s == 10.0
    writer.observe(1.0)
    clock.t = 11.0
    assert writer.window_values() == []            # 10s window in force


def test_registry_get_or_create_identity():
    reg = MetricsRegistry()
    a = reg.counter("x_total", service="a")
    b = reg.counter("x_total", service="a")
    c = reg.counter("x_total", service="b")
    assert a is b and a is not c


def test_simulated_clock_injection():
    """Samples must carry the injected (virtual) clock, not wall time."""
    sim = {"now": 0.0}
    reg = MetricsRegistry(clock=lambda: sim["now"])
    h = reg.histogram("request_latency_seconds", window_s=5.0, service="svc")
    ts = reg.series("replicas_ts", service="svc")
    sim["now"] = 100.0
    h.observe(0.3)
    ts.record(2)
    sim["now"] = 104.0
    assert h.window_values() == [0.3]
    sim["now"] = 106.0               # window measured in virtual time
    assert h.window_values() == []
    assert ts.points() == [(100.0, 2.0)]
    snap = reg.snapshot()
    assert snap["ts"] == 106.0


def test_to_prometheus_text():
    reg = MetricsRegistry(clock=FakeClock(1.0))
    reg.counter("requests_total", service="svc").inc(3)
    reg.gauge("queue_depth", service="svc").set(2)
    reg.gauge("running_tasks").set(1)
    h = reg.histogram("request_latency_seconds", service="svc")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    text = reg.to_prometheus_text()
    lines = text.splitlines()
    assert "# TYPE requests_total counter" in lines
    assert 'requests_total{service="svc"} 3' in lines
    assert "# TYPE queue_depth gauge" in lines
    assert 'queue_depth{service="svc"} 2' in lines
    assert "running_tasks 1" in lines                  # label-free metric
    assert "# TYPE request_latency_seconds summary" in lines
    assert ('request_latency_seconds{service="svc",quantile="0.5"} 0.2'
            in lines)
    assert 'request_latency_seconds_count{service="svc"} 3' in lines
    assert 'request_latency_seconds_sum{service="svc"} 0.6' in lines
    assert text.endswith("\n")


def test_prometheus_families_are_contiguous_and_escaped():
    reg = MetricsRegistry()
    # interleave creation order across two families
    reg.gauge("queue_depth", service="svc").set(1)
    reg.gauge("utilization", service="svc").set(0.5)
    reg.gauge("queue_depth", service="svc", engine="e0").set(2)
    reg.counter("requests_total", service='we"ird\nsvc').inc()
    lines = reg.to_prometheus_text().splitlines()
    qd = [i for i, l in enumerate(lines) if l.startswith("queue_depth")]
    assert qd == list(range(qd[0], qd[0] + len(qd)))   # one contiguous block
    assert 'requests_total{service="we\\"ird\\nsvc"} 1' in lines


def test_prometheus_empty_histogram_is_nan_not_crash():
    reg = MetricsRegistry()
    reg.histogram("request_latency_seconds", service="svc")
    text = reg.to_prometheus_text()
    assert 'quantile="0.99"} NaN' in text


def test_flight_record_ring_and_order():
    clock = FakeClock()
    reg = MetricsRegistry(clock=clock, flight_capacity=4)
    for i in range(6):
        clock.t = float(i)
        reg.record_event("evict", task=f"t{i}")
    dump = reg.flight_record()
    assert len(dump["events"]) == 4                    # ring bound
    assert [e[2]["task"] for e in dump["events"]] == ["t2", "t3", "t4", "t5"]
    assert [e[0] for e in dump["events"]] == [2.0, 3.0, 4.0, 5.0]
    assert dump["ts"] == 5.0


def test_flight_record_series_tail():
    clock = FakeClock()
    reg = MetricsRegistry(clock=clock)
    ts = reg.series("replicas_ts", service="svc")
    for i in range(100):
        clock.t = float(i)
        ts.record(i)
    dump = reg.flight_record(series_tail=8)
    tail = dump["series_tail"]["replicas_ts{service=svc}"]
    assert len(tail) == 8 and tail[-1] == (99.0, 99.0)


def test_snapshot_schema():
    reg = MetricsRegistry(clock=FakeClock(7.0))
    reg.counter("requests_total", service="svc").inc()
    reg.gauge("queue_depth", service="svc").set(3)
    reg.histogram("request_latency_seconds", service="svc").observe(0.1)
    reg.series("replicas_ts", service="svc").record(1)
    snap = reg.snapshot()
    assert set(snap) == {"ts", "counters", "gauges", "histograms", "series"}
    assert snap["counters"]["requests_total{service=svc}"] == 1.0
    assert snap["gauges"]["queue_depth{service=svc}"] == 3.0
    hist = snap["histograms"]["request_latency_seconds{service=svc}"]
    assert {"count", "p50", "p95", "p99", "mean", "max"} <= set(hist)
    assert snap["series"]["replicas_ts{service=svc}"] == [(7.0, 1.0)]

def test_prometheus_drops_nonfinite_gauge_tombstones():
    """NaN/inf gauges are in-process tombstones (evacuate() poisons
    spec_accept_rate); a literal ``nan`` sample breaks strict scrapers, so
    the exporter must drop the series — header and all."""
    reg = MetricsRegistry()
    reg.gauge("spec_accept_rate", service="svc").set(math.nan)
    reg.gauge("kv_occupancy", service="svc").set(math.inf)
    reg.gauge("queue_depth", service="svc").set(2.0)
    text = reg.to_prometheus_text()
    assert 'queue_depth{service="svc"} 2' in text
    assert "spec_accept_rate" not in text
    assert "kv_occupancy" not in text
    # the tombstone stays visible in-process (that's its job)
    snap = reg.snapshot()
    assert math.isnan(snap["gauges"]["spec_accept_rate{service=svc}"])
    # histogram quantiles legitimately report NaN ("no data in window")
    reg.histogram("request_latency_seconds", service="svc")
    assert 'quantile="0.99"} NaN' in reg.to_prometheus_text()


def test_quantile_clamps_out_of_range_q():
    clock = FakeClock()
    h = Histogram(clock, window_s=60.0)
    h.observe(1.0)
    h.observe(3.0)
    assert h.quantile(2.0) == 3.0        # q > 1 clamps to max, no IndexError
    assert h.quantile(-1.0) == 1.0       # q < 0 clamps to min
    assert h.quantile(1.0) == 3.0


def test_empty_pruned_window_sentinel_is_nan():
    """The documented contract: a fully-pruned window yields NaN quantiles
    (not 0, not a crash) while the cumulative count/sum survive."""
    clock = FakeClock()
    h = Histogram(clock, window_s=5.0)
    h.observe(2.0)
    clock.t = 100.0                      # sample aged out of the window
    assert h.window_values() == []
    for q in (0.0, 0.5, 0.99, 1.0):
        assert math.isnan(h.quantile(q))
    s = h.summary()
    assert s["count"] == 1 and s["window_count"] == 0
    assert math.isnan(s["p50"]) and math.isnan(s["p99"])


def test_event_seq_monotonic_and_capped_under_concurrent_writers():
    import threading

    reg = MetricsRegistry(flight_capacity=64)
    n_threads, per = 8, 100

    def spam(k):
        for i in range(per):
            reg.record_event("spam", thread=k, i=i)

    threads = [threading.Thread(target=spam, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = reg.flight_record()["events"]
    assert len(evs) == 64                          # ring cap held
    seqs = [e[3] for e in evs]
    assert seqs == sorted(seqs)                    # total order recoverable
    assert len(set(seqs)) == len(seqs)             # no duplicate seq
    assert seqs[-1] == n_threads * per - 1         # every write numbered


def test_flight_record_to_file_round_trip(tmp_path):
    import json

    clock = FakeClock()
    reg = MetricsRegistry(clock=clock)
    reg.record_event("engine_admit", rid="r0", slot=1)
    clock.t = 2.0
    reg.record_event("engine_retire", rid="r0")
    reg.series("replicas_ts", service="svc").record(1.0)
    path = str(tmp_path / "flight.json")
    assert reg.flight_record_to_file(path, engine="eng0",
                                     error="boom") == path
    doc = json.loads((tmp_path / "flight.json").read_text())
    assert doc["context"] == {"engine": "eng0", "error": "boom"}
    kinds = [e["kind"] for e in doc["events"]]
    assert kinds == ["engine_admit", "engine_retire"]
    assert [e["seq"] for e in doc["events"]] == [0, 1]
    assert doc["events"][0]["fields"] == {"rid": "r0", "slot": 1}
    assert doc["series_tail"]["replicas_ts{service=svc}"] == [[2.0, 1.0]]
