"""CRI/OCI command mapping (paper Table 3): every orchestration service maps
to the specified CRI call + annotations, and the engine translates it to the
right Funky runtime command without violating the CRI message structure."""

import time

import pytest

from repro.core import TaskImage, TaskStatus, make_cluster
from repro.core.cri import (A_PREEMPTIBLE, A_PRIORITY, A_REPLICA_OF,
                            A_SNAPSHOT, A_SOURCE_NODE, ContainerConfig)

IMAGES = {
    "img": TaskImage(name="img", kind="train", arch="yi-9b-smoke",
                     seq_len=16, global_batch=4, total_steps=15, chunks=2),
}


@pytest.fixture(scope="module")
def cluster():
    cl = make_cluster(num_nodes=2, slices_per_node=1, images=IMAGES)
    yield cl
    cl.stop()


def test_deploy_maps_to_create_start(cluster):
    agent = cluster.agent("node0")
    agent.deploy("c1", "img", priority=3, preemptible=True)
    rt = cluster.nodes["node0"].runtime
    assert rt.tasks["c1"].priority == 3
    assert rt.tasks["c1"].preemptible
    assert rt.wait("c1", timeout=600) == TaskStatus.DONE


def test_stop_container_evicts_preemptible(cluster):
    agent = cluster.agent("node0")
    agent.deploy("c2", "img")
    rt = cluster.nodes["node0"].runtime
    agent.evict("c2")                       # StopContainer -> evict
    assert rt.status("c2") == TaskStatus.EVICTED
    agent.resume("c2")                      # StartContainer -> resume
    assert rt.wait("c2", timeout=600) == TaskStatus.DONE


def test_migrate_uses_source_node_annotation(cluster):
    a0, a1 = cluster.agent("node0"), cluster.agent("node1")
    a0.deploy("c3", "img")
    a0.evict("c3")
    # CreateContainer(cid*, node_id*) -> StartContainer: Table 3 migrate row
    a1.migrate_in("c3", "img", source_node="node0")
    rt1 = cluster.nodes["node1"].runtime
    assert rt1.wait("c3", timeout=600) == TaskStatus.DONE
    assert "c3" not in cluster.nodes["node0"].runtime.tasks


def test_checkpoint_and_restore_annotations(cluster):
    a0, a1 = cluster.agent("node0"), cluster.agent("node1")
    a0.deploy("c4", "img")
    path = a0.checkpoint("c4")              # CheckpointContainer
    assert path
    a0.engine.runtime.kill("c4")
    a1.restore("c5", path)                  # snapshot annotation
    rt1 = cluster.nodes["node1"].runtime
    assert rt1.wait("c5", timeout=600) == TaskStatus.DONE


def test_replicate_annotations(cluster):
    a0, a1 = cluster.agent("node0"), cluster.agent("node1")
    a0.deploy("c6", "img")
    a1.replicate_in("c6-r", "c6", source_node="node0")
    rt1 = cluster.nodes["node1"].runtime
    assert rt1.wait("c6-r", timeout=600) == TaskStatus.DONE


def test_update_vfpga_num(cluster):
    a0 = cluster.agent("node0")
    a0.deploy("c7", "img")
    a0.update("c7", 4)                      # UpdateContainerResources
    rt0 = cluster.nodes["node0"].runtime
    assert rt0.tasks["c7"].vfpga_num == 4
    assert rt0.wait("c7", timeout=600) == TaskStatus.DONE


def test_annotations_are_plain_kv_pairs():
    cfgmsg = ContainerConfig(cid="x", image_ref="img", annotations={
        A_PREEMPTIBLE: "true", A_PRIORITY: "2",
        A_SOURCE_NODE: "node0", A_SNAPSHOT: "/p", A_REPLICA_OF: "y"})
    for k, v in cfgmsg.annotations.items():
        assert isinstance(k, str) and isinstance(v, str)
        assert k.startswith("funky.io/")    # namespaced, CRI-compliant
