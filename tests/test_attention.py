"""Attention correctness: blockwise == naive, MLA absorb == naive, hypothesis
shape sweeps."""

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # property tests skip; the rest of the module runs
    HAS_HYPOTHESIS = False

from repro.models.attention import sdpa_blockwise, sdpa_naive


def _qkv(key, B, Sq, Skv, Hq, Hkv, hd, hd_v=None):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd_v or hd), jnp.float32)
    return q, k, v


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        B=st.integers(1, 3),
        S=st.sampled_from([16, 32, 64]),
        Hkv=st.sampled_from([1, 2, 4]),
        G=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([16, 32]),
        causal=st.booleans(),
        window=st.sampled_from([0, 8, 24]),
        chunk=st.sampled_from([8, 16, 32]),
    )
    def test_blockwise_matches_naive(B, S, Hkv, G, hd, causal, window, chunk):
        if window and not causal:
            window = 0
        q, k, v = _qkv(jax.random.PRNGKey(B * 1000 + S), B, S, S,
                       Hkv * G, Hkv, hd)
        ref = sdpa_naive(q, k, v, causal=causal, window=window)
        out = sdpa_blockwise(q, k, v, causal=causal, window=window,
                             chunk=chunk)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
else:
    def test_blockwise_matches_naive():
        pytest.importorskip("hypothesis")


def test_mla_head_dim_mismatch_supported():
    # v head dim != qk head dim (MLA): both paths must handle it
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 32, 32, 4, 4, 24, hd_v=16)
    ref = sdpa_naive(q, k, v, causal=True)
    out = sdpa_blockwise(q, k, v, causal=True, chunk=8)
    assert ref.shape == (2, 32, 4, 16)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_softcap():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 16, 16, 2, 2, 16)
    a = sdpa_naive(q, k, v, causal=True, softcap=20.0)
    b = sdpa_blockwise(q, k, v, causal=True, softcap=20.0, chunk=8)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_mla_absorb_equals_decompressed():
    from repro.configs import get_arch
    from repro.models.attention import (init_mla, mla_decode, mla_prefill)

    cfg = get_arch("deepseek-v3-671b-smoke")
    p = init_mla(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    _, cache = mla_prefill(cfg, p, x)
    xt = x[:, -1:, :]
    out_a, _ = mla_decode(cfg, p, xt, jnp.int32(15), cache, absorb=True)
    out_n, _ = mla_decode(cfg, p, xt, jnp.int32(15), cache, absorb=False)
    d = float(jnp.max(jnp.abs(out_a.astype(jnp.float32)
                              - out_n.astype(jnp.float32))))
    assert d < 0.05, d


def test_ring_buffer_decode_beyond_capacity():
    """Sliding-window cache: decoding past the window must match a fresh
    full-context computation restricted to the window."""
    from repro.configs import get_arch
    from repro.models.attention import (gqa_cache_init, gqa_decode, gqa_fwd,
                                        init_gqa)
    import dataclasses

    cfg = dataclasses.replace(get_arch("yi-9b-smoke"), sliding_window=8,
                              dtype="float32")
    p = init_gqa(cfg, jax.random.PRNGKey(4))
    B, S = 1, 24
    xs = jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model))
    # sequential decode through a ring cache of capacity == window
    cache = gqa_cache_init(cfg, B, S, window=8)
    assert cache["k"].shape[1] == 8
    outs = []
    for t in range(S):
        o, cache = gqa_decode(cfg, p, xs[:, t:t + 1], jnp.int32(t), cache)
        outs.append(o[:, 0])
    got = jnp.stack(outs, axis=1)
    want = gqa_fwd(cfg, p, xs, impl="naive")
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4
