"""Property-based tests of Algorithm 1 (paper §3.5, Table 5 policies)."""

import itertools

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # property tests skip; the rest of the module runs
    HAS_HYPOTHESIS = False

from repro.core.scheduler import (Action, FunkyScheduler, Policy, SchedTask,
                                  TaskState)


class FakeView:
    def __init__(self, capacity):
        self.capacity = dict(capacity)
        self.used = {n: 0 for n in capacity}

    def nodes(self):
        return list(self.capacity)

    def free_slices(self, node):
        return self.capacity[node] - self.used[node]

    def running_tasks(self, node):
        return []

    def apply(self, sched, actions):
        for a in actions:
            if a.kind in ("deploy", "resume", "migrate"):
                self.used[a.node] += 1
            elif a.kind == "evict":
                self.used[a.node] -= 1


def _drive(policy, n_nodes, slices, tasks):
    view = FakeView({f"node{i}": slices for i in range(n_nodes)})
    sched = FunkyScheduler(policy)
    log = []
    for t in tasks:
        sched.submit(t)
    for _ in range(len(tasks) * 3 + 3):
        actions = sched.schedule_once(view)
        if not actions:
            break
        view.apply(sched, actions)
        log.extend(actions)
        # capacity invariant after every pass
        for n in view.nodes():
            assert 0 <= view.used[n] <= view.capacity[n]
    return sched, view, log


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(
        policy=st.sampled_from(list(Policy)),
        n_nodes=st.integers(1, 4),
        slices=st.integers(1, 2),
        prios=st.lists(st.integers(0, 3), min_size=1, max_size=10),
    )
    def test_capacity_and_queue_conservation(policy, n_nodes, slices, prios):
        tasks = [SchedTask(tid=f"t{i}", priority=p, submit_time=i)
                 for i, p in enumerate(prios)]
        sched, view, log = _drive(policy, n_nodes, slices, tasks)
        # each task is in exactly one queue
        in_wait = {t.tid for t in sched.wait_queue}
        in_run = {t.tid for t in sched.run_queue}
        assert not (in_wait & in_run)
        assert len(in_run) <= n_nodes * slices
        # non-preemptive policies never evict
        if policy in (Policy.FCFS, Policy.NO_PRE):
            assert not [a for a in log if a.kind == "evict"]
        # only PRE_MG migrates
        if policy is not Policy.PRE_MG:
            assert not [a for a in log if a.kind == "migrate"]

    @settings(max_examples=40, deadline=None)
    @given(prios=st.lists(st.integers(0, 3), min_size=2, max_size=8))
    def test_preemption_always_favors_higher_priority(prios):
        """PRE_EV: an evicted task's priority is strictly lower than a task
        that was scheduled in the same pass."""
        tasks = [SchedTask(tid=f"t{i}", priority=p, submit_time=i)
                 for i, p in enumerate(prios)]
        view = FakeView({"node0": 1})
        sched = FunkyScheduler(Policy.PRE_EV)
        for t in tasks:
            sched.submit(t)
            actions = sched.schedule_once(view)
            view.apply(sched, actions)
            evicted = [a for a in actions if a.kind == "evict"]
            placed = [a for a in actions if a.kind in ("deploy", "resume")]
            for e in evicted:
                ep = next(x.priority for x in tasks if x.tid == e.tid)
                assert any(
                    next(x.priority for x in tasks if x.tid == p.tid) > ep
                    for p in placed)
else:
    def test_capacity_and_queue_conservation():
        pytest.importorskip("hypothesis")

    def test_preemption_always_favors_higher_priority():
        pytest.importorskip("hypothesis")


def test_fcfs_is_head_of_line_blocking():
    tasks = [SchedTask(tid="low", priority=0, submit_time=0),
             SchedTask(tid="high", priority=9, submit_time=1)]
    view = FakeView({"node0": 1})
    sched = FunkyScheduler(Policy.FCFS)
    for t in tasks:
        sched.submit(t)
    actions = sched.schedule_once(view)
    assert [a.tid for a in actions] == ["low"]


def test_no_pre_reorders_by_priority():
    tasks = [SchedTask(tid="low", priority=0, submit_time=0),
             SchedTask(tid="high", priority=9, submit_time=1)]
    view = FakeView({"node0": 1})
    sched = FunkyScheduler(Policy.NO_PRE)
    for t in tasks:
        sched.submit(t)
    actions = sched.schedule_once(view)
    assert actions[0].tid == "high"


def test_pre_ev_resumes_on_context_node_only():
    sched = FunkyScheduler(Policy.PRE_EV)
    view = FakeView({"node0": 1, "node1": 1})
    evicted = SchedTask(tid="e", priority=1, submit_time=0,
                        state=TaskState.EVICTED, node_id="node0")
    view.used["node0"] = 1          # home is busy
    sched.submit(evicted)
    actions = sched.schedule_once(view)
    # node1 is free but PRE_EV cannot migrate a context
    assert not [a for a in actions if a.tid == "e"]


def test_pre_mg_migrates_when_home_busy():
    sched = FunkyScheduler(Policy.PRE_MG)
    view = FakeView({"node0": 1, "node1": 1})
    evicted = SchedTask(tid="e", priority=1, submit_time=0,
                        state=TaskState.EVICTED, node_id="node0")
    view.used["node0"] = 1
    sched.submit(evicted)
    actions = sched.schedule_once(view)
    mig = [a for a in actions if a.kind == "migrate"]
    assert mig and mig[0].node == "node1" and mig[0].src_node == "node0"
