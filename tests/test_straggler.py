"""Straggler mitigation: a task on a degraded node is detected by its
progress rate and migrated (context intact) to a healthy node."""

import time

import pytest

from repro.core import Policy, TaskImage, TaskStatus, make_cluster
from repro.core.scheduler import TaskState
from repro.core.tasks import TrainTask


class SlowTrainTask(TrainTask):
    """Simulates a degraded node: every step stalls."""

    def step(self, cl, gs):
        time.sleep(0.6)
        return super().step(cl, gs)


class SlowImage(TaskImage):
    def instantiate(self):
        if getattr(self, "_slow", False):
            return SlowTrainTask(self)
        return super().instantiate()


def test_straggler_detected_and_migrated():
    img = SlowImage(name="j", kind="train", arch="yi-9b-smoke", seq_len=16,
                    global_batch=4, total_steps=40, chunks=1)
    slow_img = SlowImage(name="j-slow", kind="train", arch="yi-9b-smoke",
                         seq_len=16, global_batch=4, total_steps=40, chunks=1)
    slow_img._slow = True
    cl = make_cluster(num_nodes=4, slices_per_node=1,
                      images={"j": img, "j-slow": slow_img},
                      policy=Policy.PRE_MG)
    orch = cl.orchestrator
    orch.start(tick_interval=0.02)
    fast = [orch.submit("j") for _ in range(3)]
    slow = orch.submit("j-slow")
    # let everything boot and make measurable progress
    deadline = time.time() + 300
    acted = []
    while time.time() < deadline and not acted:
        time.sleep(1.0)
        if all(orch._sched_tasks[c].state == TaskState.RUNNING
               or orch.deployments[c].status == "done"
               for c in fast + [slow]):
            acted = orch.check_stragglers(min_relative_rate=0.5)
        # fast tasks may finish before detection; that's fine if slow acted
        if orch.deployments[slow].status == "done":
            break
    events = [e for _, e, _ in orch.events]
    if acted:
        assert slow in acted
        assert "straggler_evicted" in events
    assert orch.wait_all(timeout=600)
    orch.stop()
    cl.stop()
