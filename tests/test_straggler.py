"""Straggler mitigation: a task on a degraded node is detected by its
progress rate and migrated (context intact) to a healthy node."""

import time

import pytest

from repro.core import Policy, TaskImage, TaskStatus, make_cluster
from repro.core.scheduler import TaskState
from repro.core.tasks import TrainTask


class SlowTrainTask(TrainTask):
    """Simulates a degraded node: every step stalls."""

    def step(self, cl, gs):
        time.sleep(0.6)
        return super().step(cl, gs)


class SlowImage(TaskImage):
    def instantiate(self):
        if getattr(self, "_slow", False):
            return SlowTrainTask(self)
        return super().instantiate()


def test_migrate_trace_links_pre_and_post():
    """A migrated task's pre/post traces are span-linked with
    relation="migrates" (mirroring the router's "recovers" links), and
    the link survives the chrome export trace_dump reads."""
    from repro.core.scheduler import Policy
    from repro.obs import Tracer, export_chrome_trace

    tracer = Tracer(capacity=256, sample_rate=1.0)
    img = TaskImage(name="j", kind="train", arch="yi-9b-smoke", seq_len=16,
                    global_batch=4, total_steps=150, chunks=1)
    cl = make_cluster(num_nodes=2, slices_per_node=1, images={"j": img},
                      policy=Policy.PRE_MG, tracer=tracer)
    orch = cl.orchestrator
    orch.start(tick_interval=0.02)
    cid = orch.submit("j")
    st = orch._sched_tasks[cid]

    def wait(cond, timeout=120):
        deadline = time.time() + timeout
        while time.time() < deadline:
            if cond():
                return True
            time.sleep(0.05)
        return False

    assert wait(lambda: st.state == TaskState.RUNNING
                and st.node_id is not None)
    node = st.node_id
    # mirror check_stragglers' eviction half (its *decision* machinery
    # needs >= 3 measurable peers and a rate window; the link plumbing
    # through _execute is what is under test here)
    orch.agents[node].evict(cid)
    pre = orch.tracer.event_span("orch.migrate_out", cid=cid, node=node)
    pre.finish()
    with orch._lock:
        orch.scheduler.task_done(cid)
        st.state = TaskState.EVICTED
        st.meta["migrate_from"] = node
        orch.scheduler.submit(st)
    orch._pending_migrate_links[cid] = pre
    assert wait(lambda: st.state == TaskState.RUNNING)
    assert wait(lambda: not orch._pending_migrate_links)
    post = [t for t in tracer.traces() if t.name == "orch.migrate_in"]
    assert post, "no post-migration trace emitted"
    link = post[0].links[0]
    assert link["relation"] == "migrates"
    assert link["trace_id"] == pre.trace_id
    # the exported form trace_dump renders carries the link too
    import json
    import sys
    import tempfile

    sys.path.insert(0, "tools")
    try:
        from trace_dump import links_of, spans_by_trace
    finally:
        sys.path.pop(0)
    with tempfile.NamedTemporaryFile("w", suffix=".json") as f:
        export_chrome_trace(tracer, f.name)
        doc = json.load(open(f.name))
    roots = [ev for evs in spans_by_trace(doc).values() for ev in evs
             if links_of(ev)]
    assert any(lk.get("relation") == "migrates"
               for ev in roots for lk in links_of(ev))
    assert orch.wait_all(timeout=600)
    orch.stop()
    cl.stop()


def test_straggler_detected_and_migrated():
    img = SlowImage(name="j", kind="train", arch="yi-9b-smoke", seq_len=16,
                    global_batch=4, total_steps=40, chunks=1)
    slow_img = SlowImage(name="j-slow", kind="train", arch="yi-9b-smoke",
                         seq_len=16, global_batch=4, total_steps=40, chunks=1)
    slow_img._slow = True
    cl = make_cluster(num_nodes=4, slices_per_node=1,
                      images={"j": img, "j-slow": slow_img},
                      policy=Policy.PRE_MG)
    orch = cl.orchestrator
    orch.start(tick_interval=0.02)
    fast = [orch.submit("j") for _ in range(3)]
    slow = orch.submit("j-slow")
    # let everything boot and make measurable progress
    deadline = time.time() + 300
    acted = []
    while time.time() < deadline and not acted:
        time.sleep(1.0)
        if all(orch._sched_tasks[c].state == TaskState.RUNNING
               or orch.deployments[c].status == "done"
               for c in fast + [slow]):
            acted = orch.check_stragglers(min_relative_rate=0.5)
        # fast tasks may finish before detection; that's fine if slow acted
        if orch.deployments[slow].status == "done":
            break
    events = [e for _, e, _ in orch.events]
    if acted:
        assert slow in acted
        assert "straggler_evicted" in events
    assert orch.wait_all(timeout=600)
    orch.stop()
    cl.stop()
