"""Serving substrate: generate loop, cache init, long-context decode."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import bundle_for, params_for
from repro.configs import get_arch
from repro.models import input_specs
from repro.serve import cache_bytes, generate, init_caches_from_specs


def test_generate_shapes_and_determinism():
    b = bundle_for("qwen3-8b-smoke")
    params = params_for("qwen3-8b-smoke")
    prompt = {"tokens": (jnp.arange(2 * 16, dtype=jnp.int32)
                         .reshape(2, 16) % 100)}
    out1 = generate(b, params, prompt, 6)
    out2 = generate(b, params, prompt, 6)
    assert out1.shape == (2, 6)
    assert (out1 == out2).all()          # greedy is deterministic


def test_generate_with_temperature():
    b = bundle_for("qwen3-8b-smoke")
    params = params_for("qwen3-8b-smoke")
    prompt = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    out = generate(b, params, prompt, 4, temperature=1.0,
                   rng=jax.random.PRNGKey(3))
    assert out.shape == (1, 4)


def test_cache_init_from_specs_sentinels():
    cfg = get_arch("qwen3-8b")
    specs = input_specs(cfg, dataclasses.replace(
        __import__("repro.configs", fromlist=["SHAPES"]).SHAPES["decode_32k"],
        seq_len=64, global_batch=2))
    caches = init_caches_from_specs(specs["caches"])
    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    kv_pos = [v for p, v in flat
              if any(getattr(k, "key", None) == "kv_pos" for k in p)]
    assert kv_pos and all(int(v.reshape(-1)[0]) == 2 ** 30 for v in kv_pos)
    assert cache_bytes(caches) > 0


def test_ssm_long_decode_constant_state():
    """SSM decode state does not grow with context length (long_500k)."""
    cfg = get_arch("mamba2-1.3b-smoke")
    b = bundle_for("mamba2-1.3b-smoke")
    params = params_for("mamba2-1.3b-smoke")
    logits, caches = jax.jit(b.prefill_fn)(
        params, {"tokens": jnp.zeros((1, 32), jnp.int32)})
    size0 = cache_bytes(caches)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(5):
        logits, caches = jax.jit(b.decode_fn)(
            params, tok, jnp.int32(32 + i), caches)
    assert cache_bytes(caches) == size0   # O(1) state


def test_hybrid_cache_is_window_bounded():
    """RecurrentGemma decode cache stays O(window), not O(context)."""
    cfg = get_arch("recurrentgemma-9b")
    from repro.models.transformer import lm_cache_specs

    specs_long = lm_cache_specs(cfg, 1, 524_288)
    flat = jax.tree_util.tree_flatten_with_path(specs_long)[0]
    for p, leaf in flat:
        if any(getattr(k, "key", None) == "k" for k in p):
            assert leaf.shape[2] == cfg.sliding_window  # 2048, not 524288
