"""Unified placement layer: warm-cache affinity, failure-domain
anti-affinity, group-aware victim selection, the scale-out path through
``Orchestrator.place_replica``, the metrics-driven ``MigrationController``,
and a hypothesis state machine over ``FunkyScheduler``/``PlacementPolicy``
invariants (no slice oversubscription within a pass, no lost/duplicated
tasks across evict/resume/migrate, anti-affinity honored when feasible)."""

import math

import pytest

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import RuleBasedStateMachine, invariant, rule
    HAS_HYPOTHESIS = True
except ImportError:      # property tests skip; the rest of the module runs
    HAS_HYPOTHESIS = False

from repro.core.orchestrator import Orchestrator
from repro.core.placement import (M_NODE_PROGRESS_RATE, M_TASK_PROGRESS,
                                  MigrationController, PlacementPolicy,
                                  ServiceGroup, _median)
from repro.core.scheduler import (FunkyScheduler, Policy, SchedTask,
                                  TaskState)
from repro.scaling.metrics import MetricsRegistry


class RichView:
    """Enriched fake ClusterView: capacity + failure domains + warm caches."""

    def __init__(self, capacity, domains=None, warm=None):
        self.capacity = dict(capacity)
        self.used = {n: 0 for n in capacity}
        self.domains = domains or {n: n for n in capacity}
        self.warm = {n: set() for n in capacity}
        for n, progs in (warm or {}).items():
            self.warm[n] = set(progs)

    def nodes(self):
        return list(self.capacity)

    def free_slices(self, node):
        return self.capacity[node] - self.used[node]

    def running_tasks(self, node):
        return []

    def failure_domain(self, node):
        return self.domains[node]

    def warm_programs(self, node):
        return self.warm[node]


# ---------------------------------------------------------------------------
# scoring: warmth and anti-affinity
# ---------------------------------------------------------------------------
def test_warm_cache_breaks_free_slice_ties():
    """Equal free slices: the node already holding the task's compiled
    programs wins (the name tie-break would otherwise pick n1)."""
    view = RichView({"n0": 2, "n1": 2},
                    warm={"n0": {"prefill_8", "decode_step"}})
    pol = PlacementPolicy()
    task = SchedTask(tid="t", meta={"programs": ("prefill_8",
                                                 "decode_step")})
    assert pol.select_node(task, view, {}) == "n0"
    # without the warm hint, the old most-free rule (name tie-break) holds
    cold = SchedTask(tid="t2")
    assert pol.select_node(cold, view, {}) == "n1"


def test_capacity_outweighs_warmth():
    view = RichView({"n0": 3, "n1": 3},
                    warm={"n0": {"prefill_8"}})
    view.used["n0"] = 2                      # warm but nearly full
    task = SchedTask(tid="t", meta={"programs": ("prefill_8",)})
    assert PlacementPolicy().select_node(task, view, {}) == "n1"


def test_group_replicas_spread_across_failure_domains():
    """Replicas of one service land in distinct domains when capacity
    allows; only once every domain is occupied do they double up."""
    domains = {"n0": "d0", "n1": "d0", "n2": "d1", "n3": "d1"}
    view = RichView({n: 1 for n in domains}, domains=domains)
    sched = FunkyScheduler(Policy.PRE_MG)
    for i in range(3):
        sched.submit(SchedTask(tid=f"r{i}", group="svc", submit_time=i))
    actions = sched.schedule_once(view)
    assert len(actions) == 3
    placed_domains = [domains[a.node] for a in actions]
    # first two replicas take distinct domains; the third must collide
    assert set(placed_domains[:2]) == {"d0", "d1"}
    assert sorted(placed_domains) == ["d0", "d0", "d1"] or \
        sorted(placed_domains) == ["d0", "d1", "d1"]


def test_anti_affinity_dominates_free_slices():
    """A conflict-free domain with one free slice beats a same-domain node
    with many free slices — anti-affinity is lexicographic, not a weight."""
    domains = {"n0": "d0", "n1": "d0", "n2": "d1"}
    view = RichView({"n0": 1, "n1": 3, "n2": 1}, domains=domains)
    view.used["n0"] = 1                      # base replica runs here
    base = SchedTask(tid="base", group="svc", state=TaskState.RUNNING,
                     node_id="n0")
    probe = SchedTask(tid="probe", group="svc")
    got = PlacementPolicy().select_node(probe, view, {}, running=[base])
    assert got == "n2"


def test_group_aware_victim_protects_last_replica():
    """Preemption never takes a service's last running replica while an
    equal-priority alternative exists — but will when it must."""
    pol = PlacementPolicy()
    svc = SchedTask(tid="svc-0", priority=0, group="svc",
                    state=TaskState.RUNNING, node_id="n0")
    batch = SchedTask(tid="batch", priority=0,
                      state=TaskState.RUNNING, node_id="n1")
    high = SchedTask(tid="high", priority=5)
    assert pol.find_victim(high, [svc, batch], set()).tid == "batch"
    # two replicas: the group survives losing one, so replicas are fair game
    svc2 = SchedTask(tid="svc-1", priority=0, group="svc",
                     state=TaskState.RUNNING, node_id="n2")
    assert pol.find_victim(high, [svc, svc2, batch], set()).tid == "svc-0"
    # no alternative: the last replica is still evicted (capacity wins)
    assert pol.find_victim(high, [svc], set()).tid == "svc-0"


def test_migrate_from_flag_overrides_home_resume():
    """A straggler evicted *for migration* must not bounce back onto the
    degraded node just because its own freed slice made it look free — it
    lands elsewhere when anywhere else has room, and only falls back to
    the flagged node when it is the sole option."""
    pol = PlacementPolicy()
    view = RichView({"n0": 1, "n1": 1})
    t = SchedTask(tid="t", state=TaskState.EVICTED, node_id="n0",
                  meta={"migrate_from": "n0"})
    assert pol.select_node(t, view, {}) == "n1"
    view.used["n1"] = 1                      # nowhere else: home it is
    assert pol.select_node(t, view, {}) == "n0"
    view.used["n1"] = 0
    # PRE_EV cannot migrate contexts, so the flag is ignored
    assert pol.select_node(t, view, {}, allow_migrate=False) == "n0"
    # the scheduler consumes the flag on placement: a later eviction of
    # the same task resumes on its (new) home node as usual
    sched = FunkyScheduler(Policy.PRE_MG)
    sched.submit(t)
    actions = sched.schedule_once(view)
    assert [(a.kind, a.node) for a in actions] == [("migrate", "n1")]
    assert "migrate_from" not in t.meta


def test_service_group_gather():
    a = SchedTask(tid="a", group="g1", node_id="n0")
    b = SchedTask(tid="b", group="g1", node_id="n1")
    c = SchedTask(tid="c")
    groups = ServiceGroup.gather([a, b, c])
    assert set(groups) == {"g1"}
    assert groups["g1"].domains(lambda n: n) == {"n0": 1, "n1": 1}


# ---------------------------------------------------------------------------
# scale-out path: Orchestrator.place_replica (acceptance criteria)
# ---------------------------------------------------------------------------
class FakeAgent:
    def __init__(self, slices=1, domain=None, warm=()):
        self.failed = False
        self.failure_domain = domain
        self._slices = slices
        self._warm = tuple(warm)

    def num_slices(self):
        return self._slices

    def warm_programs(self):
        return self._warm


def _orch_with_running_base(agents, image_programs):
    orch = Orchestrator(agents)
    cid = orch.submit("svc")
    orch._image_programs["svc"] = tuple(image_programs)
    st = orch._sched_tasks[cid]
    st.state = TaskState.RUNNING
    st.node_id = "n0"
    orch.scheduler.wait_queue.remove(st)
    orch.scheduler.run_queue.append(st)
    return orch, cid


def test_scale_out_prefers_warm_node_at_equal_free_slices():
    progs = ("prefill_8", "decode_step")
    orch, cid = _orch_with_running_base(
        {"n0": FakeAgent(domain="d0"),
         "n1": FakeAgent(domain="d1", warm=progs),
         "n2": FakeAgent(domain="d1")},           # cold, same domain as n1
        progs)
    assert orch.place_replica(cid) == "n1"
    # group bookkeeping: base and future replicas share the group id
    assert orch.deployments[cid].group == cid
    assert orch._sched_tasks[cid].group == cid


def test_scale_out_spreads_replicas_across_domains():
    orch, cid = _orch_with_running_base(
        {"n0": FakeAgent(domain="d0"),
         "n1": FakeAgent(slices=3, domain="d0"),  # roomy but same domain
         "n2": FakeAgent(domain="d1")},
        ())
    assert orch.place_replica(cid) == "n2"


def test_scale_out_returns_none_when_full():
    orch, cid = _orch_with_running_base({"n0": FakeAgent(domain="d0")}, ())
    assert orch.place_replica(cid) is None


# ---------------------------------------------------------------------------
# the simulator runs the same placement engine
# ---------------------------------------------------------------------------
def _trace_job(jid, t, **kw):
    from repro.core.traces import TraceJob
    return TraceJob(jid=jid, submit_time=t, duration=30.0, priority=0,
                    memory_bytes=1 << 20, fail_frac=None, **kw)


def test_simulator_warm_cache_skips_reconfiguration():
    """A node that already compiled a job's programs is warm: the second
    deploy skips ``reconfig_s``, so submit-to-finish latency drops — the
    overhead the placement layer's warm-cache affinity is chasing."""
    from repro.core.simulator import SimParams, Simulator

    cold = Simulator([_trace_job("a", 0.0, programs=("p1",)),
                      _trace_job("b", 100.0, programs=("p2",))],
                     num_nodes=1).run()
    warm = Simulator([_trace_job("a", 0.0, programs=("p1",)),
                      _trace_job("b", 100.0, programs=("p1",))],
                     num_nodes=1).run()
    reconfig = SimParams().reconfig_s
    assert warm["mean_latency_s"] == pytest.approx(
        cold["mean_latency_s"] - reconfig / 2)


def test_simulator_spreads_group_across_synthetic_domains():
    from repro.core.simulator import Simulator

    jobs = [_trace_job(f"r{i}", 0.0, group="svc") for i in range(2)]
    sim = Simulator(jobs, num_nodes=4, failure_domains=2)
    rep = sim.run()
    assert rep["completed"] == 2
    doms = {sim.cluster.domains[sim.tasks[f"r{i}"].node_id]
            for i in range(2)}
    assert doms == {"dom0", "dom1"}


# ---------------------------------------------------------------------------
# metrics-driven migration
# ---------------------------------------------------------------------------
def test_median_even_count():
    """The old probe took the upper element for even counts."""
    assert _median([1.0, 2.0, 3.0, 4.0]) == 2.5
    assert _median([1.0, 2.0, 3.0]) == 2.0
    assert math.isnan(_median([]))


def test_migration_controller_flags_straggler_from_registry():
    t = [0.0]
    reg = MetricsRegistry(clock=lambda: t[0])
    ctl = MigrationController(reg)
    running = {"c0": "n0", "c1": "n0", "c2": "n1", "c3": "n1"}
    for cid in running:
        ctl.observe(cid, 0)
    t[0] = 2.0
    for cid, step in {"c0": 20, "c1": 20, "c2": 20, "c3": 2}.items():
        ctl.observe(cid, step)
    decisions = ctl.decide(running)
    assert [d.cid for d in decisions] == ["c3"]
    assert decisions[0].rate == pytest.approx(1.0)
    assert decisions[0].median == pytest.approx(10.0)
    # the signal lives in the shared registry, not a private probe
    assert len(reg.series(M_TASK_PROGRESS, cid="c3")) == 2
    assert reg.gauge(M_NODE_PROGRESS_RATE, node="n0").value == \
        pytest.approx(10.0)
    assert reg.gauge(M_NODE_PROGRESS_RATE, node="n1").value == \
        pytest.approx(5.5)
    # after a migration the task's history resets: not instantly re-flagged
    ctl.reset("c3")
    assert ctl.decide(running) == []
    # a node whose tasks all left gets its rate gauge zeroed (no stale
    # placement bonus), and forgotten tasks drop their series entirely
    for cid in ("c2", "c3"):
        running.pop(cid)
        ctl.forget(cid)
    ctl.decide(running)
    assert reg.gauge(M_NODE_PROGRESS_RATE, node="n1").value == 0.0
    assert len(reg.series(M_TASK_PROGRESS, cid="c3")) == 0


def test_migration_controller_even_median_not_overtriggered():
    """Rates [4, 6, 10, 12]: proper median 8 -> threshold 4 -> no
    straggler.  The old upper-element median (10 -> threshold 5) would have
    migrated a healthy task."""
    t = [0.0]
    reg = MetricsRegistry(clock=lambda: t[0])
    ctl = MigrationController(reg)
    running = {c: "n0" for c in ("c0", "c1", "c2", "c3")}
    for cid in running:
        ctl.observe(cid, 0)
    t[0] = 1.0
    for cid, step in {"c0": 4, "c1": 6, "c2": 10, "c3": 12}.items():
        ctl.observe(cid, step)
    assert ctl.decide(running) == []


def test_migration_controller_needs_peers_and_window():
    t = [0.0]
    reg = MetricsRegistry(clock=lambda: t[0])
    ctl = MigrationController(reg)
    running = {"c0": "n0", "c1": "n1"}
    for cid in running:
        ctl.observe(cid, 0)
    t[0] = 2.0
    ctl.observe("c0", 20)
    ctl.observe("c1", 1)
    assert ctl.decide(running) == []          # only 2 peers (< min_peers)
    t[0] = 2.1
    running["c2"] = "n2"
    ctl.observe("c2", 0)
    assert ctl.decide(running) == []          # c2's window too short


# ---------------------------------------------------------------------------
# hypothesis state machine: scheduler + placement invariants
# ---------------------------------------------------------------------------
if HAS_HYPOTHESIS:
    DOMAINS = {"node0": "dom0", "node1": "dom1", "node2": "dom0",
               "node3": "dom1"}

    class PlacementMachine(RuleBasedStateMachine):
        """Random submit/schedule/finish interleavings under PRE_MG.

        Invariants checked after every scheduling pass:
        * no node is ever oversubscribed (replaying the pass's actions in
          order never exceeds capacity);
        * no task is lost or duplicated across deploy/evict/resume/migrate
          (every submitted task sits in exactly one of wait/run/done);
        * in eviction-free passes, a grouped deploy never lands in an
          occupied failure domain while a conflict-free node with a free
          slice existed (anti-affinity honored whenever feasible).
        """

        def __init__(self):
            super().__init__()
            self.view = RichView({n: 2 for n in DOMAINS}, domains=DOMAINS)
            self.sched = FunkyScheduler(Policy.PRE_MG)
            self.tasks = {}
            self.done = set()
            self.count = 0

        @rule(prio=st.integers(0, 3),
              group=st.sampled_from([None, "svcA", "svcB"]))
        def submit(self, prio, group):
            tid = f"t{self.count}"
            t = SchedTask(tid=tid, priority=prio, submit_time=self.count,
                          group=group)
            self.count += 1
            self.tasks[tid] = t
            self.sched.submit(t)

        @rule(idx=st.integers(0, 7))
        def finish(self, idx):
            if not self.sched.run_queue:
                return
            t = self.sched.run_queue[idx % len(self.sched.run_queue)]
            self.sched.task_done(t.tid)
            self.view.used[t.node_id] -= 1
            t.state = TaskState.DONE
            self.done.add(t.tid)

        @rule()
        def tick(self):
            pre_groups = {}
            for t in self.sched.run_queue:
                if t.group and t.node_id:
                    pre_groups.setdefault(t.group, []).append(
                        DOMAINS[t.node_id])
            free = {n: self.view.free_slices(n) for n in self.view.nodes()}
            actions = self.sched.schedule_once(self.view)
            evicted_in_pass = any(a.kind == "evict" for a in actions)
            for a in actions:
                if a.kind == "evict":
                    free[a.node] += 1
                    self.view.used[a.node] -= 1
                    continue
                if a.kind == "deploy" and not evicted_in_pass:
                    grp = self.tasks[a.tid].group
                    if grp:
                        occupied = set(pre_groups.get(grp, []))
                        feasible = any(
                            free[n] > 0 and DOMAINS[n] not in occupied
                            for n in self.view.nodes())
                        if feasible:
                            assert DOMAINS[a.node] not in occupied, (
                                f"{a.tid} ({grp}) stacked into "
                                f"{DOMAINS[a.node]} with a conflict-free "
                                f"free node available")
                free[a.node] -= 1
                assert free[a.node] >= 0, f"{a.node} oversubscribed"
                self.view.used[a.node] += 1
                grp = self.tasks[a.tid].group
                if grp:
                    pre_groups.setdefault(grp, []).append(DOMAINS[a.node])

        @invariant()
        def capacity_and_conservation(self):
            for n in self.view.nodes():
                assert 0 <= self.view.used[n] <= self.view.capacity[n]
            in_wait = {t.tid for t in self.sched.wait_queue}
            in_run = {t.tid for t in self.sched.run_queue}
            assert not (in_wait & in_run)
            assert not (in_wait & self.done)
            assert not (in_run & self.done)
            assert in_wait | in_run | self.done == set(self.tasks)
            # run-queue occupancy matches the view's accounting
            assert len(in_run) == sum(self.view.used.values())

    PlacementMachine.TestCase.settings = settings(
        max_examples=40, stateful_step_count=30, deadline=None)
    TestPlacementMachine = PlacementMachine.TestCase
else:
    def test_placement_state_machine():
        pytest.importorskip("hypothesis")
