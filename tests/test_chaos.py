"""Deterministic fault injection + end-to-end recovery (chaos soak).

Unit layer: FaultPlan determinism/bounds, retry backoff accounting, and
monitor EXECUTE retry bit-exactness.

Soak layer: five seeded fault schedules through the live cluster — node
crash mid-decode, transient EXECUTE faults, a torn checkpoint write, a
corrupted snapshot, and a failing restore — each asserting *request
conservation* (every request completes exactly once, zero duplicates,
zero replay mismatches) and *bit-exact* tokens against the fault-free
baseline run.
"""

import time

import numpy as np
import pytest

from repro.chaos import (FaultPlan, FaultSpec, InjectedCrash, InjectedFault,
                         RetryPolicy, retry_call)
from repro.core import TaskImage, make_cluster
from repro.scaling.metrics import MetricsRegistry
from repro.scaling.serving import reset_router, wait_for_service
from repro.serve.engine import ServeRequest

ARCH = "yi-9b-smoke"
PROMPT_LEN = 8
PAGE = 4
MAX_NEW = 6
SLOTS = 2
SPEC = [4, 6, 3, 5, 4, 6]              # max_new_tokens per request


def make_requests(seed=17):
    rng = np.random.Generator(np.random.Philox(seed))
    return [ServeRequest(rid=f"r{i}",
                         prompt=rng.integers(0, 100, PROMPT_LEN),
                         max_new_tokens=n)
            for i, n in enumerate(SPEC)]


# ---------------------------------------------------------------------------
# FaultPlan / retry unit layer
# ---------------------------------------------------------------------------
def _drive(plan, n=40):
    return [plan.check("monitor.execute", key=f"t:{i}") is not None
            for i in range(n)]


def test_fault_plan_deterministic():
    """Same seed + specs over the same event sequence -> identical fires."""
    mk = lambda: FaultPlan([FaultSpec(site="monitor.execute", prob=0.3,
                                      max_fires=5)], seed=42)
    a, b = _drive(mk()), _drive(mk())
    assert a == b and sum(a) == 5          # max_fires bounds total fires
    c = _drive(FaultPlan([FaultSpec(site="monitor.execute", prob=0.3,
                                    max_fires=5)], seed=43))
    assert a != c                          # and the seed actually matters


def test_fault_plan_at_every_match():
    plan = FaultPlan([
        FaultSpec(site="agent.deploy", at=2),
        FaultSpec(site="monitor.execute", every=3, max_fires=2,
                  match="svc-a"),
    ])
    fires = [plan.check("agent.deploy", key=f"n{i}") is not None
             for i in range(4)]
    assert fires == [False, True, False, False]
    # match filters the event count too: svc-b events don't advance svc-a
    assert plan.check("monitor.execute", key="svc-b:p") is None
    hits = [plan.check("monitor.execute", key="svc-a:p") is not None
            for i in range(9)]
    assert hits == [False, False, True] * 2 + [False, False, False]
    assert [f[0] for f in plan.fired] == ["agent.deploy",
                                          "monitor.execute",
                                          "monitor.execute"]


def test_fault_plan_records_registry_events():
    reg = MetricsRegistry()
    plan = FaultPlan([FaultSpec(site="ckpt.save", at=1, kind="torn")],
                     registry=reg)
    with pytest.raises(InjectedCrash):
        plan.raise_if("ckpt.save", key="/ck/p:buf")
    kinds = [e[1] for e in reg.flight_record()["events"]]
    assert "fault_injected" in kinds


def test_retry_call_backoff_and_deadline():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise InjectedFault("boom")
        return "ok"

    pol = RetryPolicy(max_attempts=4, base_backoff_s=0.1, max_backoff_s=1.0)
    assert retry_call(flaky, pol, sleep=sleeps.append) == "ok"
    assert sleeps == [0.1, 0.2]            # exponential
    # exhaustion re-raises the transient; non-retryable passes through
    with pytest.raises(InjectedFault):
        retry_call(lambda: (_ for _ in ()).throw(InjectedFault("x")),
                   RetryPolicy(max_attempts=2, base_backoff_s=0),
                   sleep=lambda s: None)
    with pytest.raises(ValueError):
        retry_call(lambda: (_ for _ in ()).throw(ValueError("v")), pol,
                   sleep=sleeps.append)


# ---------------------------------------------------------------------------
# Monitor EXECUTE retry: injected transient faults cost a backoff, not
# correctness — the transcript stays bit-exact vs the fault-free run
# ---------------------------------------------------------------------------
def _engine_factory(chaos=None, registry=None, retries=3, **eng_kw):
    from repro.core import FunkyCL, Monitor, SliceAllocator
    from repro.serve.engine import ContinuousBatchingEngine

    reg = registry if registry is not None else MetricsRegistry()
    mon = Monitor("eng-chaos", SliceAllocator("n0", 1), telemetry=reg,
                  chaos=chaos,
                  retry=RetryPolicy(max_attempts=retries,
                                    base_backoff_s=0.001,
                                    max_backoff_s=0.01))
    eng = ContinuousBatchingEngine(ARCH, FunkyCL(mon), slots=SLOTS,
                                   prompt_len=PROMPT_LEN,
                                   max_new_tokens=MAX_NEW, registry=reg,
                                   page_size=PAGE, **eng_kw)
    eng.setup()
    return mon, eng


@pytest.fixture(scope="module")
def baseline_tokens():
    """Fault-free per-request tokens — the bit-exactness reference for
    every soak schedule (greedy decode is deterministic, so batching
    composition and replica identity must not change them)."""
    mon, eng = _engine_factory()
    for r in make_requests():
        eng.submit(r)
    eng.run_until_drained()
    ref = {rid: list(rec.tokens) for rid, rec in eng.completed.items()}
    mon.vfpga_exit()
    assert sorted(ref) == [f"r{i}" for i in range(len(SPEC))]
    return ref


def test_monitor_execute_retry_bit_exact(baseline_tokens):
    reg = MetricsRegistry()
    plan = FaultPlan([FaultSpec(site="monitor.execute", kind="error",
                                every=11, max_fires=2)],
                     seed=1, registry=reg)
    mon, eng = _engine_factory(chaos=plan, registry=reg)
    for r in make_requests():
        eng.submit(r)
    eng.run_until_drained()
    got = {rid: list(rec.tokens) for rid, rec in eng.completed.items()}
    mon.vfpga_exit()
    assert got == baseline_tokens
    assert len(plan.fired) == 2
    snap = reg.snapshot()
    assert snap["counters"]["monitor_execute_retries_total"] == 2
    kinds = [e[1] for e in reg.flight_record()["events"]]
    assert kinds.count("execute_retry") == 2


def test_monitor_execute_retry_exhaustion_fails_request():
    """A persistent fault exhausts the bounded retries and surfaces as a
    structured failure, not a hang."""
    reg = MetricsRegistry()
    plan = FaultPlan([FaultSpec(site="monitor.execute", kind="error",
                                every=1, max_fires=10,
                                match="decode_step")], registry=reg)
    mon, eng = _engine_factory(chaos=plan, registry=reg)
    eng.submit(make_requests()[0])
    with pytest.raises(InjectedFault):
        eng.run_until_drained()
    mon.vfpga_exit()
    snap = reg.snapshot()
    assert snap["counters"]["monitor_execute_failed_total"] >= 1
    kinds = [e[1] for e in reg.flight_record()["events"]]
    assert "execute_failed" in kinds


def test_pipelined_execute_error_surfaces_exactly_once(baseline_tokens):
    """Regression for the step()-boundary drop: a pipelined fused EXECUTE
    that errors *after* the step that submitted it must surface exactly
    once (the old loop only raised for already-done completions, then
    cleared the list — late failures were silently dropped and their
    stale tokens committed).  After the raise the engine rolls the span
    back, resubmits deterministically, and finishes bit-exactly."""
    reg = MetricsRegistry()
    plan = FaultPlan([FaultSpec(site="monitor.execute", kind="error",
                                at=3, max_fires=1, match="decode_multi")],
                     seed=7, registry=reg)
    # retries=1: InjectedFault is transient, so the monitor's default
    # retry loop would absorb it before it ever reached the engine
    mon, eng = _engine_factory(chaos=plan, registry=reg, retries=1,
                               fuse_steps=4, async_depth=1)
    for r in make_requests():
        eng.submit(r)
    raises = 0
    guard = 0
    while not eng.idle:
        try:
            eng.step()
        except InjectedFault:
            raises += 1
        guard += 1
        assert guard < 10000, "engine did not drain"
    got = {rid: list(rec.tokens) for rid, rec in eng.completed.items()}
    mon.vfpga_exit()
    assert len(plan.fired) == 1
    assert raises == 1, f"EXECUTE failure surfaced {raises} times, want 1"
    assert got == baseline_tokens


def test_delayed_pipelined_execute_carried_to_next_boundary(baseline_tokens):
    """A fused EXECUTE that is merely *slow* is not done at the boundary
    of the step that submitted it: it must be carried forward, folded into
    attribution exactly once, and never mistaken for a failure."""
    plan = FaultPlan([FaultSpec(site="monitor.execute", kind="delay",
                                delay_s=0.05, at=2, max_fires=2,
                                match="decode")], seed=8)
    mon, eng = _engine_factory(chaos=plan, fuse_steps=4, async_depth=1)
    for r in make_requests():
        eng.submit(r)
    eng.run_until_drained()
    got = {rid: list(rec.tokens) for rid, rec in eng.completed.items()}
    split = eng.host_device_split()
    mon.vfpga_exit()
    assert len(plan.fired) >= 1
    assert got == baseline_tokens
    # attribution folded each EXECUTE exactly once: the queue-wait gauge
    # denominator equals the EXECUTE tally (satellite: it used to count
    # every read/write/sync completion too)
    assert eng._attr_reqs == eng._attr_execs == split["execs"]


# ---------------------------------------------------------------------------
# Chaos soak: seeded schedules over the live cluster
# ---------------------------------------------------------------------------
def _soak(plan, inject, *, num_nodes=2, seed=17):
    """Deploy one engine-serve replica, feed it SPEC requests, run the
    schedule's mid-flight ``inject(ctx)`` hook, and wait for every request
    to terminate.  Returns (router, orch, registry, plan)."""
    reg = MetricsRegistry()
    if plan is not None and plan.registry is None:
        plan.registry = reg
    img = TaskImage(name="chaos-svc", kind="engine-serve", arch=ARCH,
                    prompt_len=PROMPT_LEN, global_batch=SLOTS,
                    total_steps=10 ** 9, max_new_tokens=MAX_NEW,
                    page_size=PAGE)
    cluster = make_cluster(num_nodes=num_nodes, slices_per_node=1,
                           images={"chaos-svc": img}, metrics=reg,
                           chaos=plan)
    router = reset_router("chaos-svc")
    orch = cluster.orchestrator
    orch.start(tick_interval=0.01)
    try:
        cid = orch.submit("chaos-svc")
        node = wait_for_service(cluster, orch, cid, timeout_s=300)
        for r in make_requests(seed):
            router.submit(r)
        ctx = {"cluster": cluster, "orch": orch, "router": router,
               "cid": cid, "node": node, "plan": plan}
        inject(ctx)
        deadline = time.time() + 300
        while router.outstanding() > 0 and time.time() < deadline:
            time.sleep(0.02)
        missing = sorted({r.rid for r in make_requests(seed)}
                         - set(router.completed))
        assert router.outstanding() == 0, f"requests lost: {missing}"
        return router, orch, reg, ctx
    finally:
        router.close()
        cluster.stop()


def _assert_conserved(router, baseline_tokens):
    """Zero lost, zero duplicated, bit-exact vs the fault-free run."""
    assert sorted(router.completed) == sorted(baseline_tokens)
    assert router.duplicates == 0
    assert router.replay_mismatches == 0
    got = {rid: list(rec.tokens) for rid, rec in router.completed.items()}
    assert got == baseline_tokens


def _wait_completions(router, n, timeout=300):
    deadline = time.time() + timeout
    while len(router.completed) < n and time.time() < deadline:
        time.sleep(0.01)
    assert len(router.completed) >= n


def test_soak_node_crash_mid_decode(baseline_tokens):
    """Schedule 1: checkpoint, then hard-crash the serving node while
    requests are in flight.  Leased requests replay through the router;
    the restored replica finishes everything bit-exactly."""
    def inject(ctx):
        _wait_completions(ctx["router"], 2)
        ctx["orch"].checkpoint(ctx["cid"])
        ctx["orch"].handle_node_failure(ctx["node"])

    router, orch, reg, _ = _soak(None, inject)
    _assert_conserved(router, baseline_tokens)
    events = [e[1] for e in orch.events]
    assert "restored" in events or "resubmitted" in events
    assert "router_replay" in events or len(router.replayed) == 0


def test_soak_transient_execute_faults(baseline_tokens):
    """Schedule 2: seeded transient EXECUTE faults throughout the run —
    absorbed by the monitor's retry loop, invisible to clients."""
    plan = FaultPlan([FaultSpec(site="monitor.execute", kind="error",
                                every=7, max_fires=3, match="task-")],
                     seed=2)
    router, _, reg, _ = _soak(plan, lambda ctx: None)
    _assert_conserved(router, baseline_tokens)
    assert len(plan.fired) == 3
    assert reg.snapshot()["counters"][
        "monitor_execute_retries_total"] == 3


def test_soak_torn_checkpoint_then_crash(baseline_tokens):
    """Schedule 3: first checkpoint lands, second is torn mid-write (the
    simulated crash leaves only hidden debris).  Node failure then
    restores from the intact first checkpoint."""
    plan = FaultPlan(seed=3)

    def inject(ctx):
        _wait_completions(ctx["router"], 1)
        p1 = ctx["orch"].checkpoint(ctx["cid"])
        plan.add(FaultSpec(site="ckpt.save", kind="torn", at=1))
        with pytest.raises(InjectedCrash):
            ctx["orch"].checkpoint(ctx["cid"])
        # the torn attempt must never be discoverable as a snapshot
        assert ctx["orch"]._latest_snapshot_any(ctx["cid"]) == p1
        ctx["orch"].handle_node_failure(ctx["node"])

    router, orch, _, _ = _soak(plan, inject)
    _assert_conserved(router, baseline_tokens)
    assert "restored" in [e[1] for e in orch.events]


def test_soak_corrupt_snapshot_falls_back(baseline_tokens):
    """Schedule 4: the newest checkpoint is bit-flipped on disk after
    publish.  Restore detects the digest mismatch and falls back along
    the incremental chain to the previous good snapshot, logging
    ``restore_fallback``."""
    plan = FaultPlan(seed=4)

    def inject(ctx):
        _wait_completions(ctx["router"], 1)
        p1 = ctx["orch"].checkpoint(ctx["cid"])
        # let the guest advance so the second checkpoint lands at a new
        # step — a same-step save would *overwrite* p1, not chain to it
        step1 = int(p1.rsplit("-step", 1)[1])
        gs = (ctx["cluster"].agent(ctx["node"]).engine.runtime
              .tasks[ctx["cid"]].guest_state)
        deadline = time.time() + 60
        while gs.step <= step1 and time.time() < deadline:
            time.sleep(0.002)
        plan.add(FaultSpec(site="ckpt.corrupt", kind="corrupt", at=1))
        p2 = ctx["orch"].checkpoint(ctx["cid"])  # published, then corrupted
        assert p2 != p1
        ctx["orch"].handle_node_failure(ctx["node"])

    router, orch, reg, _ = _soak(plan, inject)
    _assert_conserved(router, baseline_tokens)
    events = [e[1] for e in orch.events]
    assert "restored" in events
    kinds = [e[1] for e in reg.flight_record()["events"]]
    assert "restore_fallback" in kinds


def test_soak_restore_failure_retried(baseline_tokens):
    """Schedule 5: the restore itself fails transiently on first attempt;
    the orchestrator's bounded retry-with-backoff lands it on attempt 2."""
    plan = FaultPlan([FaultSpec(site="ckpt.restore", kind="error", at=1)],
                     seed=5)

    def inject(ctx):
        _wait_completions(ctx["router"], 1)
        ctx["orch"].checkpoint(ctx["cid"])
        ctx["orch"].handle_node_failure(ctx["node"])

    router, orch, _, _ = _soak(plan, inject)
    _assert_conserved(router, baseline_tokens)
    events = [e[1] for e in orch.events]
    assert "restored" in events
    retries = [e for e in orch.events if e[1] == "action_retry"
               and e[2].get("action") == "restore"]
    assert len(retries) == 1


def test_replay_links_recovery_traces(baseline_tokens):
    """A replayed request's new trace carries a span link back to its
    crashed predecessor (same trace_id = rid), and the Chrome export puts
    the link on the root event for trace_dump to show."""
    from repro.obs import Tracer
    from repro.obs.export import validate_chrome_trace
    from repro.scaling.serving import RequestRouter

    tracer = Tracer()
    router = RequestRouter("link-svc", tracer=tracer)
    reqs = make_requests()[:2]
    for r in reqs:
        router.submit(r)
    popped = router.pop(2, engine_id="eng-a")
    for r in popped:
        r.committed = [1, 2]               # as if two tokens decoded
    n = router.fail_engine("eng-a")
    assert n == 2 and router.in_flight == 0
    assert router.pending_count() == 2     # replayed to the head
    assert router.replayed == {"r0": [1, 2], "r1": [1, 2]}
    for r in popped:
        assert r.trace is not None
        assert r.trace.links[0]["trace_id"] == r.rid
        assert r.trace.links[0]["relation"] == "recovers"
        r.trace.finish()
    doc = tracer.chrome_trace()
    validate_chrome_trace(doc)
    roots = [ev for ev in doc["traceEvents"]
             if ev.get("ph") == "X" and ev["args"].get("parent_id") == 0
             and "links" in ev["args"]]
    assert len(roots) == 2


def test_duplicate_completion_guard():
    """A dead replica's late completion of a replayed request must not
    double-count, and a replay that diverges from the committed prefix is
    flagged."""
    from repro.scaling.serving import RequestRouter
    from repro.serve.engine import CompletedRequest

    router = RequestRouter("dup-svc")
    req = make_requests()[0]
    router.submit(req)
    router.pop(1, engine_id="eng-a")
    req.committed = [5, 6]
    router.fail_engine("eng-a")
    router.pop(1, engine_id="eng-b")
    rec = CompletedRequest(rid=req.rid, tokens=[5, 6, 7], arrival_t=0,
                           admit_t=0, first_token_t=0, finish_t=1)
    router.complete(rec)
    router.complete(rec)                   # late duplicate from the dead one
    assert router.duplicates == 1
    assert len(router.completed) == 1
    assert router.replay_mismatches == 0

    router2 = RequestRouter("dup-svc2")
    req2 = make_requests()[1]
    router2.submit(req2)
    router2.pop(1, engine_id="eng-a")
    req2.committed = [9, 9]
    router2.fail_engine("eng-a")
    router2.pop(1, engine_id="eng-b")
    bad = CompletedRequest(rid=req2.rid, tokens=[1, 2, 3], arrival_t=0,
                           admit_t=0, first_token_t=0, finish_t=1)
    router2.complete(bad)
    assert router2.replay_mismatches == 1
