"""Pallas kernel validation (interpret mode) against pure-jnp oracles,
sweeping shapes/dtypes as required by the assignment."""

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # property tests skip; the rest of the module runs
    HAS_HYPOTHESIS = False

from repro.kernels.decode_attention.kernel import decode_attention_fwd
from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import sdpa_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_fwd
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_fwd
from repro.kernels.ssd_scan.ref import ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,causal,window", [
    (2, 128, 4, 2, 64, True, 0),
    (1, 128, 8, 8, 128, True, 0),
    (2, 128, 4, 1, 64, True, 32),
    (1, 64, 4, 2, 32, False, 0),
])
def test_flash_attention(B, S, Hq, Hkv, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * 7 + S), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd)).astype(dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              bq=64, bk=64, interpret=True)
    ref = sdpa_ref(q, k, v, causal=causal, window=window)
    d = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                              - ref.astype(jnp.float32))))
    assert d < TOL[dtype], d


if HAS_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(bq=st.sampled_from([32, 64, 128]),
           bk=st.sampled_from([32, 64, 128]))
    def test_flash_attention_block_shape_sweep(bq, bk):
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.float32)
        out = flash_attention_fwd(q, k, v, causal=True, bq=bq, bk=bk,
                                  interpret=True)
        ref = sdpa_ref(q, k, v, causal=True)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
else:
    def test_flash_attention_block_shape_sweep():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,cap,Hq,Hkv,hd,pos,window", [
    (2, 256, 8, 2, 64, 200, 0),
    (1, 256, 4, 4, 128, 255, 0),
    (2, 512, 8, 1, 64, 400, 128),
])
def test_decode_attention(B, cap, Hq, Hkv, hd, pos, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(cap + pos), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, cap, Hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, cap, Hkv, hd)).astype(dtype)
    kv_pos = jnp.arange(cap, dtype=jnp.int32).at[cap // 3].set(2 ** 30)
    out = decode_attention_fwd(q, k, v, pos, kv_pos, window=window, bk=128,
                               interpret=True)
    ref = decode_ref(q, k, v, pos, kv_pos, window=window)
    d = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                              - ref.astype(jnp.float32))))
    assert d < TOL[dtype], d


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(
        B=st.integers(1, 3),
        S=st.sampled_from([64, 128, 256]),
        W=st.sampled_from([128, 256]),
        bs=st.sampled_from([32, 64]),
    )
    def test_rglru_scan(B, S, W, bs):
        ks = jax.random.split(jax.random.PRNGKey(S + W), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.98
        b = jax.random.normal(ks[1], (B, S, W)) * 0.1
        h, hf = rglru_scan_fwd(a, b, bs=bs, bw=128, interpret=True)
        rh, rhf = rglru_scan_ref(a, b)
        assert float(jnp.max(jnp.abs(h - rh))) < 1e-4
        assert float(jnp.max(jnp.abs(hf - rhf))) < 1e-4
else:
    def test_rglru_scan():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,cs", [
    (2, 128, 4, 32, 64, 32),
    (1, 128, 8, 64, 128, 64),
    (2, 64, 2, 16, 32, 64),
])
def test_ssd_scan(B, S, H, P, N, cs):
    ks = jax.random.split(jax.random.PRNGKey(S * H), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y, st_ = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=cs, interpret=True)
    ry, rst = ssd_ref(x, dt, A, Bm, Cm, chunk=cs)
    rel = float(jnp.max(jnp.abs(y - ry))) / (float(jnp.max(jnp.abs(ry))) + 1e-9)
    assert rel < 1e-4
    assert float(jnp.max(jnp.abs(st_ - rst))) < 1e-3


def test_ssd_scan_chunk_invariance():
    """Different chunk sizes must give identical results (state passing)."""
    ks = jax.random.split(jax.random.PRNGKey(77), 5)
    B, S, H, P, N = 1, 128, 2, 16, 32
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y1, s1 = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    y2, s2 = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=128, interpret=True)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-3
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-3
