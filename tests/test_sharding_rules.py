"""Sharding rules: divisibility fallbacks, full coverage, spec validity.

Uses a mock mesh (16 x 16) so the rules can be exercised without 256 devices.
"""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch
from repro.models import build_model, input_specs
from repro.sharding.rules import ShardingRules


class MockMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH = MockMesh({"data": 16, "model": 16})
MESH3 = MockMesh({"pod": 2, "data": 16, "model": 16})


def _abstract_params(arch):
    cfg = get_arch(arch)
    b = build_model(cfg)
    return cfg, jax.eval_shape(b.init, jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", list(ARCHS))
def test_every_leaf_gets_a_valid_spec(arch):
    cfg, abstract = _abstract_params(arch)
    rules = ShardingRules(cfg, MESH)
    specs = rules.param_specs(abstract)
    leaves = jax.tree_util.tree_flatten_with_path(abstract)[0]
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for (path, leaf), spec in zip(leaves, spec_leaves):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        # every sharded dim must divide the axis size
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= MESH.shape[a]
            assert dim % size == 0, (arch, path, spec, leaf.shape)


def test_gqa_kv_heads_fall_back_to_replication():
    cfg, abstract = _abstract_params("yi-9b")      # kv=4 < model=16
    rules = ShardingRules(cfg, MESH)
    specs = rules.param_specs(abstract)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    wk = [s for p, s in flat if any(
        getattr(k, "key", None) == "wk" for k in p)]
    assert wk and all(s[1 if len(s) == 3 else 2] is None for s in wk)


def test_moe_experts_sharded_over_model():
    cfg, abstract = _abstract_params("deepseek-v3-671b")
    rules = ShardingRules(cfg, MESH)
    specs = rules.param_specs(abstract)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    experts = [s for p, s in flat if any(
        getattr(k, "key", None) == "w_up" for k in p) and len(s) == 4]
    assert experts                          # stacked (L, E, D, F)
    for s in experts:
        assert s[1] == "model"              # EP over the model axis


def test_cache_specs_seq_fallback():
    cfg = get_arch("qwen3-8b")              # kv=8 not divisible by 16
    rules = ShardingRules(cfg, MESH)
    specs = input_specs(cfg, SHAPES["decode_32k"])
    cs = rules.cache_specs(specs["caches"])
    flat = jax.tree_util.tree_flatten_with_path(
        cs, is_leaf=lambda x: isinstance(x, P))[0]
    k_specs = [s for p, s in flat if any(
        getattr(kk, "key", None) == "k" for kk in p)]
    assert k_specs
    for s in k_specs:
        assert s[2] == "model" and s[3] is None    # seq-sharded cache


def test_batch_replicates_when_too_small():
    cfg = get_arch("mamba2-1.3b")
    rules = ShardingRules(cfg, MESH)
    specs = input_specs(cfg, SHAPES["long_500k"])   # global_batch = 1
    cs = rules.cache_specs(specs["caches"])
    flat = jax.tree_util.tree_flatten_with_path(
        cs, is_leaf=lambda x: isinstance(x, P))[0]
    for p, s in flat:
        if len(s) >= 2 and s[1] is not None:
            raise AssertionError(f"batch=1 must not shard: {p} {s}")


def test_fsdp_policy_shards_more_than_tp():
    cfg, abstract = _abstract_params("qwen3-8b")
    tp = ShardingRules(cfg, MESH, "tp").param_specs(abstract)
    fs = ShardingRules(cfg, MESH, "fsdp_tp").param_specs(abstract)

    def sharded_dims(specs):
        return sum(sum(1 for a in s if a is not None)
                   for s in jax.tree.leaves(
                       specs, is_leaf=lambda x: isinstance(x, P)))

    assert sharded_dims(fs) > sharded_dims(tp)


def test_multipod_dp_axes():
    cfg = get_arch("yi-9b")
    rules = ShardingRules(cfg, MESH3)
    assert rules.dp == ("pod", "data")
    assert rules.dp_size == 32
